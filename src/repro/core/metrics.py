"""Partition quality metrics (Sec. II-A / VI-a).

  * edge cut          — weight of edges with endpoints in different blocks
  * comm volume       — per block b: # of vertices outside b adjacent to b
                        (data words b must receive); max over blocks is the
                        paper's maxCommVolume
  * imbalance         — max_i tw_actual(b_i)/tw_target(b_i)
  * load ratio        — objective (2): max_i |b_i| / c_s(p_i)

Hierarchical (tree-aware) metrics: given an (h-1, k) ancestor table of
the blocks (``topology.normalize_tree_of``), cut and comm volume split
exactly into per-tree-level components — every cut edge / received word
crosses a block pair with exactly one LCA level — and the *weighted tree
objective* ``sum_level lam[level] * cut[level]`` prices each level by its
link cost (``topology.LinkCosts.lams``), the objective the tree runtime's
per-level round latencies imply and the tree-aware refinement minimizes.
The PR 4 two-level (pod) metrics are the ``h == 2`` instance: their
(intra, inter) pairs are exactly the level-0/level-1 entries.
"""
from __future__ import annotations

import numpy as np

from ..sparse.graph import Graph
from .topology import LinkCosts, Topology, level_matrix


def _default_link_costs() -> LinkCosts:
    """THE default cost model for every metric that takes an optional
    ``lam``/``lams``: one resolution point, so the objective, the FM
    gains, and ``summarize_hier``/``summarize_tree`` can never disagree
    about what an unspecified lambda means.  Topology-calibrated models
    come in through the ``lam``/``lams`` arguments
    (``Topology.link_costs()``)."""
    return LinkCosts()


def _resolve_lam(lam: float | None) -> float:
    return _default_link_costs().lam if lam is None else lam


def resolve_lams(lams, h: int):
    """(h,) per-level objective weights; defaults extend the one default
    cost model geometrically to depth h (``link_costs`` ladder)."""
    if lams is None:
        base = _default_link_costs()
        ratio = base.lam
        return tuple(base.lams[l] if l < base.levels else
                     float(ratio ** l) for l in range(h))
    lams = tuple(float(x) for x in np.atleast_1d(np.asarray(lams)))
    if len(lams) != h:
        raise ValueError(f"need {h} per-level weights, got {len(lams)}")
    return lams


def edge_cut(g: Graph, part: np.ndarray) -> float:
    src, dst, w = g.edge_list()
    cut2 = np.sum(w * (part[src] != part[dst]))   # both directions counted
    return float(cut2) / 2.0


# The linearized-pair dedup key is ``recv * n + vert`` in int64: it wraps
# (silently, into negative keys that unique/sort still accept) once
# ``k * n`` approaches 2**63.  Above this threshold the dedup switches to
# a lexsort over the two columns — bit-identical output (same pairs, same
# (recv, vert) order), no products formed.
_PAIR_DEDUP_MAX = 2 ** 62


def _dedup_recv_pairs(recv: np.ndarray, vert: np.ndarray, n: int,
                      k: int) -> tuple[np.ndarray, np.ndarray]:
    """Distinct (receiving block, remote vertex) pairs, sorted by
    (recv, vert).  Returns ``(blocks, verts)`` int64 arrays."""
    recv = np.asarray(recv, dtype=np.int64)
    vert = np.asarray(vert, dtype=np.int64)
    if int(max(k, 1)) * int(n) <= _PAIR_DEDUP_MAX:   # Python ints: no wrap
        pairs = np.unique(recv * n + vert)
        return pairs // n, pairs % n
    if len(recv) == 0:
        return recv, vert
    order = np.lexsort((vert, recv))
    r_s, v_s = recv[order], vert[order]
    keep = np.ones(len(r_s), dtype=bool)
    keep[1:] = (r_s[1:] != r_s[:-1]) | (v_s[1:] != v_s[:-1])
    return r_s[keep], v_s[keep]


def comm_volumes(g: Graph, part: np.ndarray, k: int) -> np.ndarray:
    """Received-words per block: for block b, the number of distinct remote
    vertices adjacent to b (the halo size — exactly what distributed SpMV
    must fetch)."""
    src, dst, _ = g.edge_list()
    pb, pv = part[src], part[dst]
    ext = pb != pv
    # distinct (receiving block, remote vertex) pairs
    blocks, _ = _dedup_recv_pairs(pb[ext], dst[ext], g.n, k)
    return np.bincount(blocks, minlength=k)


def max_comm_volume(g: Graph, part: np.ndarray, k: int) -> int:
    return int(comm_volumes(g, part, k).max(initial=0))


def total_comm_volume(g: Graph, part: np.ndarray, k: int) -> int:
    return int(comm_volumes(g, part, k).sum())


def block_sizes_of(part: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(part, minlength=k)


def imbalance(part: np.ndarray, tw: np.ndarray) -> float:
    """max_i actual/target over blocks with a positive target — 1.0 is
    perfectly on-target.

    Blocks with ``tw == 0`` (fully saturated topologies hand some PUs a
    zero target) are correct exactly when they stay empty: an empty
    zero-target block is ignored rather than polluting the ratio, and a
    *populated* zero-target block returns ``inf`` (any load on it is a
    violation, not a ratio)."""
    tw = np.asarray(tw, dtype=np.float64)
    sizes = block_sizes_of(part, len(tw))
    pos = tw > 0
    if np.any(sizes[~pos] > 0):
        return float("inf")
    if not pos.any():
        return 1.0
    return float((sizes[pos] / tw[pos]).max())


def load_ratio(part: np.ndarray, topo: Topology) -> float:
    """Objective (2) evaluated on the realized partition."""
    sizes = block_sizes_of(part, topo.k)
    return float(np.max(sizes / topo.speeds))


def memory_violations(part: np.ndarray, topo: Topology,
                      slack: float = 0.0) -> int:
    """# of blocks violating constraint (3), with optional relative slack."""
    sizes = block_sizes_of(part, topo.k)
    return int(np.sum(sizes > topo.memories * (1.0 + slack)))


def boundary_mask(g: Graph, part: np.ndarray) -> np.ndarray:
    """Vertices with >=1 neighbor in another block."""
    src, dst, _ = g.edge_list()
    ext = part[src] != part[dst]
    mask = np.zeros(g.n, dtype=bool)
    mask[src[ext]] = True
    return mask


def summarize(g: Graph, part: np.ndarray, topo: Topology,
              tw: np.ndarray) -> dict:
    vols = comm_volumes(g, part, topo.k)
    compute = block_sizes_of(part, topo.k) / topo.speeds
    total = compute + vols
    return {
        "cut": edge_cut(g, part),
        "max_comm_volume": int(vols.max(initial=0)),
        "total_comm_volume": int(vols.sum()),
        "imbalance": imbalance(part, tw),
        "load_ratio": load_ratio(part, topo),
        "mem_violations": memory_violations(part, topo, slack=0.03),
        # per-PU modeled split of the flat (single-level) bottleneck:
        # compute = Algorithm-1 speeds x block weight, comm = dedup halo
        "per_pu_compute": compute.tolist(),
        "per_pu_comm_volume": vols.tolist(),
        "bottleneck_objective": float(total.max(initial=0.0)),
        "critical_pu": int(total.argmax()) if len(total) else 0,
    }


# -- hierarchical (tree-aware) metrics --------------------------------------

def tree_cut_split(g: Graph, part: np.ndarray,
                   anc: np.ndarray) -> np.ndarray:
    """Edge cut split by LCA level: (h,) array with
    ``tree_cut_split(...).sum() == edge_cut`` exactly — every cut edge
    connects two distinct blocks with exactly one tree-distance level
    (``topology.level_matrix``).  ``anc`` is the (h-1, k) ancestor table
    (a (k,) pod array is the two-level instance)."""
    anc = np.atleast_2d(np.asarray(anc))
    h = anc.shape[0] + 1
    lev = level_matrix(anc)
    src, dst, w = g.edge_list()
    pa, pb = part[src], part[dst]
    lev_uv = lev[pa, pb]                        # -1 for same-block pairs
    # both directions counted in each sum, halved per level
    return np.array([float(np.sum(w * (lev_uv == l))) / 2.0
                     for l in range(h)])


def tree_comm_volumes(g: Graph, part: np.ndarray, k: int,
                      anc: np.ndarray) -> np.ndarray:
    """Received-words per block split by the owner's LCA level: (h, k)
    array with column sums over levels == :func:`comm_volumes` exactly —
    each distinct (receiver, remote vertex) pair has one owning block,
    hence one level.  Row ``l`` sums to the word count the tree schedule
    moves over the level-``l`` links; ``row.max()`` is the per-level
    bottleneck volume (the Langguth/Schlag/Schulz objective)."""
    anc = np.atleast_2d(np.asarray(anc))
    h = anc.shape[0] + 1
    lev = level_matrix(anc)
    src, dst, _ = g.edge_list()
    pb, pv = part[src], part[dst]
    ext = pb != pv
    blocks, verts = _dedup_recv_pairs(pb[ext], dst[ext], g.n, k)
    owners = part[verts]
    lev_pair = lev[blocks, owners]
    return np.stack([np.bincount(blocks[lev_pair == l], minlength=k)
                     for l in range(h)])


def tree_objective(g: Graph, part: np.ndarray, anc: np.ndarray,
                   lams=None) -> float:
    """The weighted tree cut ``sum_level lam[level] * cut[level]`` — what
    the tree-aware FM gains (``refinement.fm_pair_refine(anc=...)``)
    minimize.  ``lams`` defaults to the shared cost model
    (:func:`_default_link_costs`) extended to the table's depth; at
    ``h == 2`` this is bit-identical to :func:`two_level_objective`."""
    anc = np.atleast_2d(np.asarray(anc))
    lams = resolve_lams(lams, anc.shape[0] + 1)
    cuts = tree_cut_split(g, part, anc)
    obj = 0.0
    for lam_l, cut_l in zip(lams, cuts):
        obj += lam_l * cut_l
    return float(obj)


def per_pu_model_costs(g: Graph, part: np.ndarray, anc: np.ndarray,
                       lams=None, speeds: np.ndarray | None = None,
                       c_comp: float = 1.0,
                       vw: np.ndarray | None = None) -> dict:
    """Per-PU modeled cost split of the bottleneck (makespan) objective:

      compute[i] = c_comp * w(b_i) / speed_i        (Algorithm-1 speeds)
      comm[i]    = sum_l lams[l] * vols[l, i]       (deduplicated receive
                                                     volume per tree level)

    ``anc`` is the (h-1, k) ancestor table (a (0, k) table is the flat
    single-level machine; a (k,) pod array is the two-level instance);
    ``k`` is taken from its column count.  ``speeds`` defaults to a
    homogeneous machine; ``c_comp`` converts one weight unit of modeled
    compute into the cost of one innermost-level halo word (``lams[0]``
    units), the knob a measured machine model will calibrate.  ``vw``
    supplies per-vertex weights (coarse-level supernodes).

    Returns ``{"compute": (k,), "comm": (k,), "comm_by_level": (h, k),
    "total": (k,)}`` — ``total.max()`` is :func:`bottleneck_objective`,
    ``total.argmax()`` the critical PU.
    """
    anc = np.atleast_2d(np.asarray(anc))
    h, k = anc.shape[0] + 1, anc.shape[1]
    lams = np.asarray(resolve_lams(lams, h), dtype=np.float64)
    if vw is None:
        sizes = block_sizes_of(part, k).astype(np.float64)
    else:
        sizes = np.bincount(part, weights=np.asarray(vw, np.float64),
                            minlength=k)
    speeds = (np.ones(k) if speeds is None
              else np.asarray(speeds, dtype=np.float64))
    vols = tree_comm_volumes(g, part, k, anc)
    compute = float(c_comp) * sizes / speeds
    comm = lams @ vols
    return {"compute": compute, "comm": comm, "comm_by_level": vols,
            "total": compute + comm}


def bottleneck_objective(g: Graph, part: np.ndarray, anc: np.ndarray,
                         lams=None, speeds: np.ndarray | None = None,
                         c_comp: float = 1.0,
                         vw: np.ndarray | None = None) -> float:
    """The process-mapping bottleneck (makespan) objective
    (Langguth/Schlag/Schulz): the *maximum* over PUs of modeled compute
    plus per-level weighted deduplicated receive volume,

        max_i  c_comp * w(b_i) / speed_i
               + sum_l lams[l] * |halo_l(b_i)|.

    What actually bounds a distributed CG iteration — unlike the summed
    :func:`tree_objective`, concentrating either load or halo volume on
    one PU is penalized even when the total stays flat.  Structurally it
    is also what the padded tree runtime pays: the max block size sets
    the padded rows B and the max per-level receive volume the halo slot
    count S_lvl of ``sparse.distributed.build_plan_tree``."""
    pp = per_pu_model_costs(g, part, anc, lams=lams, speeds=speeds,
                            c_comp=c_comp, vw=vw)
    return float(pp["total"].max(initial=0.0))


def pod_cut_split(g: Graph, part: np.ndarray,
                  pod_of: np.ndarray) -> tuple[float, float]:
    """Edge cut split by pod locality — the two-level instance of
    :func:`tree_cut_split`: ``(intra, inter)`` with ``intra + inter ==
    edge_cut`` exactly."""
    intra, inter = tree_cut_split(g, part,
                                  np.asarray(pod_of)[None, :])
    return float(intra), float(inter)


def pod_comm_volumes(g: Graph, part: np.ndarray, k: int,
                     pod_of: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Received-words per block split by the owner's pod — the two-level
    instance of :func:`tree_comm_volumes`: ``(intra, inter)`` (k,)
    arrays with ``intra + inter == comm_volumes`` exactly.

    ``inter.sum()`` is the total word count the hier schedule moves over
    the slow links; ``inter.max()`` the bottleneck per-PU slow-link
    volume."""
    vols = tree_comm_volumes(g, part, k, np.asarray(pod_of)[None, :])
    return vols[0], vols[1]


def two_level_objective(g: Graph, part: np.ndarray, pod_of: np.ndarray,
                        lam: float | None = None) -> float:
    """The weighted two-level cut ``intra + lam * inter`` — the ``h == 2``
    instance of :func:`tree_objective`.  ``lam`` defaults to the shared
    cost model's round-latency ratio (one resolution point with
    :func:`summarize_hier`)."""
    lam = _resolve_lam(lam)
    return tree_objective(g, part, np.asarray(pod_of)[None, :],
                          lams=(1.0, lam))


def summarize_tree(g: Graph, part: np.ndarray, topo: Topology,
                   tw: np.ndarray, anc: np.ndarray,
                   lams=None) -> dict:
    """:func:`summarize` plus the per-level cut/volume splits and the
    weighted tree objective (Table IV analogue for the tree pipeline)."""
    anc = np.atleast_2d(np.asarray(anc))
    h = anc.shape[0] + 1
    lams = resolve_lams(lams, h)
    out = summarize(g, part, topo, tw)
    cuts = tree_cut_split(g, part, anc)
    vols = tree_comm_volumes(g, part, topo.k, anc)
    obj = 0.0
    for lam_l, cut_l in zip(lams, cuts):
        obj += lam_l * cut_l
    # tree-aware bottleneck split: same lams, Algorithm-1 speeds
    compute = block_sizes_of(part, topo.k) / topo.speeds
    comm = np.asarray(lams, dtype=np.float64) @ vols
    total = compute + comm
    out.update(
        cut_by_level=cuts.tolist(),
        comm_volume_by_level=[int(v.sum()) for v in vols],
        max_comm_volume_by_level=[int(v.max(initial=0)) for v in vols],
        tree_objective=float(obj),
        lams=list(lams),
        per_pu_compute=compute.tolist(),
        per_pu_comm=comm.tolist(),
        bottleneck_objective=float(total.max(initial=0.0)),
        critical_pu=int(total.argmax()) if len(total) else 0,
    )
    return out


def summarize_hier(g: Graph, part: np.ndarray, topo: Topology,
                   tw: np.ndarray, pod_of: np.ndarray,
                   lam: float | None = None) -> dict:
    """:func:`summarize` plus the intra/inter split and the weighted
    objective — the two-level view of :func:`summarize_tree` (same
    default cost model, so the objective and the summary can't
    diverge)."""
    lam = _resolve_lam(lam)
    out = summarize_tree(g, part, topo, tw,
                         np.asarray(pod_of)[None, :], lams=(1.0, lam))
    cuts = out.pop("cut_by_level")
    vols = out.pop("comm_volume_by_level")
    maxv = out.pop("max_comm_volume_by_level")
    out.pop("lams")
    out.update(
        cut_intra=cuts[0], cut_inter=cuts[1],
        comm_volume_intra=vols[0], comm_volume_inter=vols[1],
        max_comm_volume_intra=maxv[0], max_comm_volume_inter=maxv[1],
        two_level_objective=out.pop("tree_objective"),
        lam=lam,
    )
    return out
