"""Partition quality metrics (Sec. II-A / VI-a).

  * edge cut          — weight of edges with endpoints in different blocks
  * comm volume       — per block b: # of vertices outside b adjacent to b
                        (data words b must receive); max over blocks is the
                        paper's maxCommVolume
  * imbalance         — max_i tw_actual(b_i)/tw_target(b_i)
  * load ratio        — objective (2): max_i |b_i| / c_s(p_i)

Hierarchical (pod-aware) metrics: given a pod assignment of the blocks,
cut and comm volume split exactly into an intra-pod and an inter-pod
component (every cut edge / received word crosses either a same-pod or a
pod-crossing block pair, never both), and the *weighted two-level
objective* prices the inter-pod component lambda-x higher — the
WindGP-style objective the hier runtime's round latencies imply
(``topology.LinkCosts``), minimized by the pod-aware refinement.
"""
from __future__ import annotations

import numpy as np

from ..sparse.graph import Graph
from .topology import LinkCosts, Topology


def edge_cut(g: Graph, part: np.ndarray) -> float:
    src, dst, w = g.edge_list()
    cut2 = np.sum(w * (part[src] != part[dst]))   # both directions counted
    return float(cut2) / 2.0


def comm_volumes(g: Graph, part: np.ndarray, k: int) -> np.ndarray:
    """Received-words per block: for block b, the number of distinct remote
    vertices adjacent to b (the halo size — exactly what distributed SpMV
    must fetch)."""
    src, dst, _ = g.edge_list()
    pb, pv = part[src], part[dst]
    ext = pb != pv
    # distinct (receiving block, remote vertex) pairs
    pairs = np.unique(pb[ext].astype(np.int64) * g.n + dst[ext].astype(np.int64))
    blocks = pairs // g.n
    return np.bincount(blocks, minlength=k)


def max_comm_volume(g: Graph, part: np.ndarray, k: int) -> int:
    return int(comm_volumes(g, part, k).max(initial=0))


def total_comm_volume(g: Graph, part: np.ndarray, k: int) -> int:
    return int(comm_volumes(g, part, k).sum())


def block_sizes_of(part: np.ndarray, k: int) -> np.ndarray:
    return np.bincount(part, minlength=k)


def imbalance(part: np.ndarray, tw: np.ndarray) -> float:
    """max_i actual/target over blocks with a positive target — 1.0 is
    perfectly on-target.

    Blocks with ``tw == 0`` (fully saturated topologies hand some PUs a
    zero target) are correct exactly when they stay empty: an empty
    zero-target block is ignored rather than polluting the ratio, and a
    *populated* zero-target block returns ``inf`` (any load on it is a
    violation, not a ratio)."""
    tw = np.asarray(tw, dtype=np.float64)
    sizes = block_sizes_of(part, len(tw))
    pos = tw > 0
    if np.any(sizes[~pos] > 0):
        return float("inf")
    if not pos.any():
        return 1.0
    return float((sizes[pos] / tw[pos]).max())


def load_ratio(part: np.ndarray, topo: Topology) -> float:
    """Objective (2) evaluated on the realized partition."""
    sizes = block_sizes_of(part, topo.k)
    return float(np.max(sizes / topo.speeds))


def memory_violations(part: np.ndarray, topo: Topology,
                      slack: float = 0.0) -> int:
    """# of blocks violating constraint (3), with optional relative slack."""
    sizes = block_sizes_of(part, topo.k)
    return int(np.sum(sizes > topo.memories * (1.0 + slack)))


def boundary_mask(g: Graph, part: np.ndarray) -> np.ndarray:
    """Vertices with >=1 neighbor in another block."""
    src, dst, _ = g.edge_list()
    ext = part[src] != part[dst]
    mask = np.zeros(g.n, dtype=bool)
    mask[src[ext]] = True
    return mask


def summarize(g: Graph, part: np.ndarray, topo: Topology,
              tw: np.ndarray) -> dict:
    return {
        "cut": edge_cut(g, part),
        "max_comm_volume": max_comm_volume(g, part, topo.k),
        "total_comm_volume": total_comm_volume(g, part, topo.k),
        "imbalance": imbalance(part, tw),
        "load_ratio": load_ratio(part, topo),
        "mem_violations": memory_violations(part, topo, slack=0.03),
    }


# -- hierarchical (pod-aware) metrics ---------------------------------------

def pod_cut_split(g: Graph, part: np.ndarray,
                  pod_of: np.ndarray) -> tuple[float, float]:
    """Edge cut split by pod locality: ``(intra, inter)`` with
    ``intra + inter == edge_cut`` exactly — a cut edge connects two
    distinct blocks, which either share a pod or do not."""
    pod_of = np.asarray(pod_of)
    src, dst, w = g.edge_list()
    pa, pb = part[src], part[dst]
    ext = pa != pb
    cross = pod_of[pa] != pod_of[pb]
    intra2 = np.sum(w * (ext & ~cross))
    inter2 = np.sum(w * (ext & cross))          # both directions counted
    return float(intra2) / 2.0, float(inter2) / 2.0


def pod_comm_volumes(g: Graph, part: np.ndarray, k: int,
                     pod_of: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Received-words per block split by the owner's pod: ``(intra,
    inter)`` (k,) arrays with ``intra + inter == comm_volumes`` exactly —
    each distinct (receiver, remote vertex) pair has one owning block.

    ``inter.sum()`` is the total word count the hier schedule moves over
    the slow links; ``inter.max()`` the bottleneck per-PU slow-link
    volume (the Langguth/Schlag/Schulz per-level bottleneck)."""
    pod_of = np.asarray(pod_of)
    src, dst, _ = g.edge_list()
    pb, pv = part[src], part[dst]
    ext = pb != pv
    pairs = np.unique(pb[ext].astype(np.int64) * g.n
                      + dst[ext].astype(np.int64))
    blocks = pairs // g.n
    owners = part[pairs % g.n]
    cross = pod_of[blocks] != pod_of[owners]
    intra = np.bincount(blocks[~cross], minlength=k)
    inter = np.bincount(blocks[cross], minlength=k)
    return intra, inter


def two_level_objective(g: Graph, part: np.ndarray, pod_of: np.ndarray,
                        lam: float | None = None) -> float:
    """The weighted two-level cut ``intra + lam * inter`` — what the
    pod-aware FM gains (``refinement.fm_pair_refine(pod_of=...)``)
    minimize.  ``lam`` defaults to the hier round-latency ratio
    (``LinkCosts().lam``)."""
    if lam is None:
        lam = LinkCosts().lam
    intra, inter = pod_cut_split(g, part, pod_of)
    return intra + lam * inter


def summarize_hier(g: Graph, part: np.ndarray, topo: Topology,
                   tw: np.ndarray, pod_of: np.ndarray,
                   lam: float | None = None) -> dict:
    """:func:`summarize` plus the intra/inter split and the weighted
    objective (Table IV analogue for the two-level pipeline)."""
    if lam is None:
        lam = LinkCosts().lam
    out = summarize(g, part, topo, tw)
    intra_cut, inter_cut = pod_cut_split(g, part, pod_of)
    intra_v, inter_v = pod_comm_volumes(g, part, topo.k, pod_of)
    out.update(
        cut_intra=intra_cut, cut_inter=inter_cut,
        comm_volume_intra=int(intra_v.sum()),
        comm_volume_inter=int(inter_v.sum()),
        max_comm_volume_intra=int(intra_v.max(initial=0)),
        max_comm_volume_inter=int(inter_v.max(initial=0)),
        two_level_objective=intra_cut + lam * inter_cut,
        lam=lam,
    )
    return out
