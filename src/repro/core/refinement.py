"""Combinatorial local refinement (Geographer-R, Sec. V).

Pipeline per refinement pass:
  1. build the communication (quotient) graph G_c — one vertex per block,
     edge weights = communication volume between block pairs;
  2. maximum-edge-coloring-style greedy coloring of G_c to schedule
     communication rounds (color classes = sets of disjoint block pairs that
     refine concurrently — Holtgrewe/Sanders/Schulz [20] style);
  3. per pair, pairwise FM on the extended boundary neighborhood: candidates
     are vertices within ``bfs_hops`` BFS rounds of the boundary, moves are
     gain-ordered with tentative-prefix rollback (classic FM), subject to the
     heterogeneous caps  size_i <= min(m_cap_i, (1+eps) tw_i).

In the paper each PU pair runs FM independently and keeps the better of the
two solutions; here the pairs within a color class touch disjoint blocks, so
a host-sequential sweep over the class is semantically the parallel result.
"""
from __future__ import annotations

import heapq

import numpy as np

from ..sparse.graph import Graph
from .metrics import block_sizes_of, edge_cut, resolve_lams
from .topology import level_matrix


# -- incremental volume-gain structure (bottleneck objective) ----------------

class VolumeGainTracker:
    """Net-degree-style incremental structure for the bottleneck
    objective: tracks the *distinct* remote vertices each block receives,
    split by the owner's tree level, updated in O(deg + k) per applied
    move — never recomputed from scratch.

    Invariants (checked by the hypothesis suite in
    ``tests/test_volume_gains.py`` after every applied move):

      * ``nbr_cnt[r, u]``  == number of neighbors of vertex u inside
        block r (the net-degree counters);
      * ``vols``           == ``metrics.tree_comm_volumes(g, part, k,
        anc)`` exactly (int64, so equality is exact);
      * ``sizes``          == per-block weights.

    ``apply(v, to)`` mutates the tracked ``part`` array in place and is
    its own inverse (``apply(v, frm)`` undoes), which is what the FM
    rollback and the O(deg + k) tentative ``peek`` use.  Assumes a
    simple symmetric graph with no self-loops (the CSR contract of
    ``sparse.graph.Graph``).
    """

    def __init__(self, g: Graph, part: np.ndarray, k: int,
                 anc: np.ndarray | None = None, lams=None,
                 speeds: np.ndarray | None = None, c_comp: float = 1.0,
                 vw: np.ndarray | None = None):
        self.g = g
        self.k = int(k)
        self.part = part                      # shared, mutated by apply()
        if anc is None:                       # flat machine: one level
            anc = np.zeros((0, k), dtype=np.int64)
        anc = np.atleast_2d(np.asarray(anc))
        self.h = anc.shape[0] + 1
        self.lev = np.maximum(level_matrix(anc), 0)
        self.lams = np.asarray(resolve_lams(lams, self.h),
                               dtype=np.float64)
        self.c_comp = float(c_comp)
        self.speeds = (np.ones(self.k) if speeds is None
                       else np.asarray(speeds, dtype=np.float64))
        self.vw = None if vw is None else np.asarray(vw, dtype=np.float64)
        src, dst, _ = g.edge_list()
        self.nbr_cnt = np.zeros((self.k, g.n), dtype=np.int32)
        np.add.at(self.nbr_cnt, (part[src], dst), 1)
        self.vols = np.zeros((self.h, self.k), dtype=np.int64)
        for r in range(self.k):
            remote = (self.nbr_cnt[r] > 0) & (part != r)
            self.vols[:, r] = np.bincount(self.lev[r, part[remote]],
                                          minlength=self.h)
        self.sizes = (block_sizes_of(part, self.k).astype(np.float64)
                      if self.vw is None
                      else np.bincount(part, weights=self.vw,
                                       minlength=self.k))

    def totals(self) -> np.ndarray:
        """(k,) per-PU modeled cost: compute + weighted receive volume
        (== ``metrics.per_pu_model_costs(...)['total']``)."""
        return (self.c_comp * self.sizes / self.speeds
                + self.lams @ self.vols)

    def bottleneck(self) -> float:
        """Current ``metrics.bottleneck_objective`` value."""
        return float(self.totals().max(initial=0.0))

    def critical_pu(self) -> int:
        return int(self.totals().argmax())

    def apply(self, v: int, to: int) -> None:
        """Move vertex ``v`` to block ``to``; O(deg(v) + k)."""
        v, to = int(v), int(to)
        frm = int(self.part[v])
        if frm == to:
            return
        g, lev, vols = self.g, self.lev, self.vols
        nb = g.indices[g.indptr[v]:g.indptr[v + 1]]
        own = self.part[nb]
        # receiver side: v stops/starts being a block-frm/-to neighbor of
        # each u in N(v); a 1 -> 0 (0 -> 1) transition on a remote u
        # drops (adds) u from that block's halo at the owner's level
        cnt = self.nbr_cnt[frm, nb]
        self.nbr_cnt[frm, nb] = cnt - 1
        gone = (cnt == 1) & (own != frm)
        np.subtract.at(vols, (lev[frm, own[gone]], frm), 1)
        cnt = self.nbr_cnt[to, nb]
        self.nbr_cnt[to, nb] = cnt + 1
        new = (cnt == 0) & (own != to)
        np.add.at(vols, (lev[to, own[new]], to), 1)
        # owner side: every block adjacent to v now receives it from
        # ``to`` instead of ``frm`` (at a possibly different level)
        recv = np.flatnonzero(self.nbr_cnt[:, v] > 0)
        r_rm = recv[recv != frm]
        np.subtract.at(vols, (lev[r_rm, frm], r_rm), 1)
        r_ad = recv[recv != to]
        np.add.at(vols, (lev[r_ad, to], r_ad), 1)
        w = 1.0 if self.vw is None else self.vw[v]
        self.sizes[frm] -= w
        self.sizes[to] += w
        self.part[v] = to

    def peek(self, v: int, to: int) -> float:
        """Objective after tentatively moving ``v`` — state (including
        ``part``) is restored before returning."""
        frm = int(self.part[v])
        self.apply(v, to)
        val = self.bottleneck()
        self.apply(v, frm)
        return val

    def totals_key(self) -> tuple:
        """Per-PU totals sorted descending, as a lexicographically
        comparable tuple.  ``key_a < key_b`` iff partition a is strictly
        better under the bottleneck order: smaller makespan, or equal
        makespan with a smaller second-heaviest PU, and so on.  This is
        what the bottleneck FM minimizes — comparing only the max would
        plateau as soon as two PUs tie at the top, and the overload
        could never diffuse across intermediate blocks."""
        return tuple(np.sort(self.totals())[::-1])

    def peek_key(self, v: int, to: int) -> tuple:
        """:meth:`totals_key` after tentatively moving ``v`` — state is
        restored before returning."""
        frm = int(self.part[v])
        self.apply(v, to)
        key = self.totals_key()
        self.apply(v, frm)
        return key


# -- 1. quotient graph ------------------------------------------------------

def quotient_graph(g: Graph, part: np.ndarray, k: int):
    """Block-level communication graph: returns (pairs, weights) with
    pairs (m, 2) int (a < b), weights = inter-block edge weight (cut)."""
    src, dst, w = g.edge_list()
    pa, pb = part[src], part[dst]
    ext = pa < pb
    key = pa[ext].astype(np.int64) * k + pb[ext]
    order = np.argsort(key, kind="stable")
    key_s, w_s = key[order], w[ext][order]
    uniq, start = np.unique(key_s, return_index=True)
    wsum = np.add.reduceat(w_s, start) if len(w_s) else np.zeros(0)
    pairs = np.stack([uniq // k, uniq % k], axis=1).astype(np.int32)
    return pairs, wsum


# -- 2. edge coloring -------------------------------------------------------

def greedy_edge_coloring(pairs: np.ndarray, weights: np.ndarray
                         ) -> np.ndarray:
    """Greedy edge coloring, heaviest edges first.  Returns color per edge.

    Guarantees <= 2*maxdeg - 1 colors; in practice close to maxdeg (Vizing).
    Heaviest-first means the largest communication volumes get the earliest
    rounds — matching [20]'s scheduling heuristic.
    """
    order = np.argsort(-weights, kind="stable")
    colors = -np.ones(len(pairs), dtype=np.int32)
    used: dict[int, set[int]] = {}
    for e in order:
        a, b = int(pairs[e, 0]), int(pairs[e, 1])
        ua = used.setdefault(a, set())
        ub = used.setdefault(b, set())
        c = 0
        while c in ua or c in ub:
            c += 1
        colors[e] = c
        ua.add(c)
        ub.add(c)
    return colors


def vizing_edge_coloring(pairs: np.ndarray,
                         weights: np.ndarray | None = None) -> np.ndarray:
    """Misra–Gries edge coloring: guaranteed <= maxdeg + 1 colors (Vizing's
    bound).  Returns a color per edge.

    Used for the halo-exchange round schedule in ``sparse.distributed``:
    each color class is a matching = one ppermute round, so the Delta+1
    guarantee bounds the number of rounds by quotient-graph degree + 1
    (greedy only guarantees 2*Delta - 1).  Colors are relabeled so the
    heaviest class (largest total communication volume) is round 0 —
    preserving the heaviest-first scheduling of :func:`greedy_edge_coloring`
    at class granularity.

    O(V * E) on the quotient graph — V = #blocks, tiny by construction.
    """
    m = len(pairs)
    if m == 0:
        return np.zeros(0, np.int32)
    pairs = np.asarray(pairs, dtype=np.int64)
    # at[x]: color -> (edge index, neighbor); edge_color[e] current color
    at: dict[int, dict[int, tuple[int, int]]] = {}
    for u in np.unique(pairs):
        at[int(u)] = {}
    edge_color = -np.ones(m, dtype=np.int32)
    deg = np.bincount(pairs.ravel())
    C = int(deg.max()) + 1                      # palette 0..Delta

    def free(x: int) -> int:
        cx = at[x]
        for c in range(C):
            if c not in cx:
                return c
        raise AssertionError("no free color — palette too small")

    def set_color(e: int, c: int) -> None:
        u, v = int(pairs[e, 0]), int(pairs[e, 1])
        old = int(edge_color[e])
        if old >= 0:
            at[u].pop(old, None)
            at[v].pop(old, None)
        edge_color[e] = c
        at[u][c] = (e, v)
        at[v][c] = (e, u)

    order = (np.argsort(-np.asarray(weights), kind="stable")
             if weights is not None else np.arange(m))
    for e in map(int, order):
        u, v = int(pairs[e, 0]), int(pairs[e, 1])
        # maximal fan of u starting at v
        fan = [v]
        in_fan = {v}
        while True:
            last = fan[-1]
            nxt = None
            for c_, (_e2, nbr) in at[u].items():
                if nbr not in in_fan and c_ not in at[last]:
                    nxt = nbr
                    break
            if nxt is None:
                break
            fan.append(nxt)
            in_fan.add(nxt)
        c = free(u)
        d = free(fan[-1])
        if c != d and d in at[u]:
            # invert the maximal cd-path starting at u.  Two phases (clear
            # all, then recolor all): flipping in place would transiently
            # alias two path edges onto one color at their shared endpoint
            # and the second flip would pop the first one's fresh entry.
            path = []
            x, need = u, d
            while need in at[x]:
                e2, nbr = at[x][need]
                path.append((e2, need))
                x, need = nbr, (c if need == d else d)
            for e2, col in path:
                a, b = int(pairs[e2, 0]), int(pairs[e2, 1])
                at[a].pop(col)
                at[b].pop(col)
                edge_color[e2] = -1
            for e2, col in path:
                set_color(e2, c if col == d else d)
        # w = first fan vertex with d free whose prefix is still a fan
        # (the inversion can break the fan property at one point; the lemma
        # guarantees a valid w exists at or before it)
        ucol_of = {nb: (cc, ee) for cc, (ee, nb) in at[u].items()}
        w_i = None
        for i, fv in enumerate(fan):
            if d not in at[fv]:
                w_i = i
                break
            if i + 1 < len(fan):
                nxt = ucol_of.get(fan[i + 1])
                if nxt is None or nxt[0] in at[fv]:
                    break                      # fan broken by the inversion
        assert w_i is not None, "Misra–Gries invariant violated"
        # rotate fan[0:w_i]: shift each (u, fan[i+1]) color onto (u, fan[i]);
        # the uncolored u-edge walks along the fan as colors shift down
        uncol = e                              # edge u–fan[0]
        for i in range(w_i):
            c_next, e_next = ucol_of[fan[i + 1]]
            edge_color[e_next] = -1
            at[u].pop(c_next)
            at[fan[i + 1]].pop(c_next)
            set_color(uncol, c_next)           # colors edge u–fan[i]
            uncol = e_next                     # u–fan[i+1] now uncolored
        set_color(uncol, d)

    # relabel so the heaviest color class is round 0
    w_arr = (np.asarray(weights, dtype=np.float64) if weights is not None
             else np.ones(m))
    n_col = int(edge_color.max()) + 1
    class_w = np.zeros(n_col)
    np.add.at(class_w, edge_color, w_arr)
    relabel = np.empty(n_col, dtype=np.int32)
    relabel[np.argsort(-class_w, kind="stable")] = np.arange(n_col)
    return relabel[edge_color].astype(np.int32)


# -- 3. pairwise FM ---------------------------------------------------------

def _boundary_candidates(g: Graph, part: np.ndarray, a: int, b: int,
                         bfs_hops: int, max_frac: float = 0.25
                         ) -> np.ndarray:
    """Vertices of blocks a/b within bfs_hops of the a|b boundary."""
    src, dst, _ = g.edge_list()
    on_ab = ((part[src] == a) & (part[dst] == b)) | \
            ((part[src] == b) & (part[dst] == a))
    frontier = np.unique(np.concatenate([src[on_ab], dst[on_ab]]))
    seen = np.zeros(g.n, dtype=bool)
    seen[frontier] = True
    in_pair = (part == a) | (part == b)
    for _ in range(bfs_hops):
        if len(frontier) == 0:
            break
        nbrs = []
        for v in frontier:
            nbrs.append(g.indices[g.indptr[v]:g.indptr[v + 1]])
        nxt = np.unique(np.concatenate(nbrs)) if nbrs else np.zeros(0, int)
        nxt = nxt[in_pair[nxt] & ~seen[nxt]]
        seen[nxt] = True
        frontier = nxt
    cand = np.nonzero(seen & in_pair)[0]
    # paper: "we do not consider all vertices but only a smaller number"
    cap = max(64, int(max_frac * in_pair.sum()))
    return cand[:cap]


def _level_cost_matrix(anc: np.ndarray, lams) -> np.ndarray:
    """(k, k) per-edge cost under an ancestor table: 0 on the diagonal
    (same block), ``lams[level]`` otherwise — the price the tree-aware
    FM gains charge a cut edge by the LCA level of its block pair."""
    anc = np.atleast_2d(np.asarray(anc))
    lams = resolve_lams(lams, anc.shape[0] + 1)
    lev = level_matrix(anc)
    cost = np.asarray(lams, dtype=np.float64)[np.maximum(lev, 0)]
    np.fill_diagonal(cost, 0.0)
    return cost


def _fm_pair_bottleneck(g: Graph, part: np.ndarray, a: int, b: int,
                        caps: np.ndarray, tracker: VolumeGainTracker,
                        bfs_hops: int = 2,
                        max_moves: int | None = None) -> float:
    """One bottleneck-objective FM pass between blocks a and b.

    Moves route through ``tracker.apply`` (which mutates ``part`` — the
    tracker must have been built over this very array); each step picks
    the candidate move minimizing the *global* sorted-totals vector
    lexicographically (``tracker.peek_key``, O(deg + k log k) per
    evaluation): smaller makespan first, then smaller second-heaviest
    PU, and so on — so overload drains off the critical PU and keeps
    diffusing through intermediate blocks even while the top of the
    order is momentarily tied.  Classic FM hill-climbing with
    best-prefix rollback; returns the makespan drop (>= 0; an epsilon
    when only the tail of the order improved).
    """
    assert tracker.part is part, "tracker must wrap the mutated part array"
    cand = _boundary_candidates(g, part, a, b, bfs_hops)
    if len(cand) == 0:
        return 0.0
    start = best = tracker.totals_key()
    locked = np.zeros(g.n, dtype=bool)
    history: list[tuple[int, int]] = []        # (v, frm)
    best_len = 0
    if max_moves is None:
        max_moves = min(len(cand), 64)
    vw = tracker.vw
    while len(history) < max_moves:
        best_v, best_to, best_key = -1, -1, None
        for v in cand:
            if locked[v]:
                continue
            frm = int(part[v])
            to = b if frm == a else a
            w_v = 1.0 if vw is None else vw[v]
            if tracker.sizes[to] + w_v > caps[to]:
                continue
            key = tracker.peek_key(v, to)
            if best_key is None or key < best_key:
                best_v, best_to, best_key = int(v), to, key
        if best_v < 0:
            break
        frm = int(part[best_v])
        tracker.apply(best_v, best_to)
        locked[best_v] = True
        history.append((best_v, frm))
        if best_key < best:
            best, best_len = best_key, len(history)
    for v, frm in reversed(history[best_len:]):
        tracker.apply(v, frm)
    # gain: the makespan drop; a lexicographic-only improvement (same
    # max, smaller tail) reports an epsilon so the driver keeps passing
    drop = start[0] - best[0]
    if drop > 0:
        return float(drop)
    return 1e-12 if best < start else 0.0


def fm_pair_refine(g: Graph, part: np.ndarray, a: int, b: int,
                   caps: np.ndarray, bfs_hops: int = 2,
                   max_moves: int | None = None,
                   pod_of: np.ndarray | None = None, lam: float = 1.0,
                   anc: np.ndarray | None = None, lams=None,
                   vw: np.ndarray | None = None,
                   objective: str = "cut",
                   tracker: VolumeGainTracker | None = None) -> float:
    """One FM pass between blocks a and b.  Mutates ``part``.

    Returns the achieved gain (>= 0; rolls back to the best prefix).

    ``objective="bottleneck"`` switches the gains to the makespan
    objective (:func:`_fm_pair_bottleneck`): pass the shared
    :class:`VolumeGainTracker` built over this ``part`` array (it holds
    the global per-(receiver, level) volumes a bottleneck move gain
    depends on); ``anc``/``lams`` then live on the tracker.

    With ``anc`` (an (h-1, k) ancestor table, + ``lams``) the gains are
    computed against the *weighted tree objective*
    (``metrics.tree_objective``): a cut edge costs ``lams[level]`` at
    the LCA level of its block pair, so moves that pull an edge down the
    tree — off the slower links — are worth proportionally more.
    ``pod_of`` (+ ``lam``) is the two-level sugar: exactly
    ``anc=pod_of[None], lams=(1, lam)``, bit-identical to the PR 4 pod
    path.  Without either, the gain is the flat cut (every cut edge
    costs 1), bit-identical to the pre-pod-aware behavior.

    ``vw`` (n,) supplies per-vertex weights for the size/cap accounting
    (coarse-level supernodes in the multilevel pipeline); ``caps`` is
    then in weight units, not vertex counts.
    """
    if objective == "bottleneck":
        if tracker is None:
            raise ValueError("objective='bottleneck' needs the shared "
                             "VolumeGainTracker (tracker=)")
        return _fm_pair_bottleneck(g, part, a, b, caps, tracker,
                                   bfs_hops=bfs_hops, max_moves=max_moves)
    if objective != "cut":
        raise ValueError(f"unknown objective {objective!r}")
    if pod_of is not None:
        if anc is not None:
            raise ValueError("pass either pod_of= (two-level) or anc= "
                             "(tree), not both")
        anc = np.asarray(pod_of)[None, :]
        lams = (1.0, lam)
    cand = _boundary_candidates(g, part, a, b, bfs_hops)
    if len(cand) == 0:
        return 0.0
    if vw is None:
        sizes = block_sizes_of(part, len(caps)).astype(np.float64)
    else:
        vw = np.asarray(vw, dtype=np.float64)
        sizes = np.bincount(part, weights=vw, minlength=len(caps))

    if anc is None:
        def gain_of(v: int) -> float:
            nb = g.indices[g.indptr[v]:g.indptr[v + 1]]
            wv = g.weights[g.indptr[v]:g.indptr[v + 1]]
            own, other = (a, b) if part[v] == a else (b, a)
            return float(np.sum(wv * (part[nb] == other))
                         - np.sum(wv * (part[nb] == own)))
    else:
        C = _level_cost_matrix(anc, lams)       # per-pair LCA-level price

        def gain_of(v: int) -> float:
            nb = g.indices[g.indptr[v]:g.indptr[v + 1]]
            wv = g.weights[g.indptr[v]:g.indptr[v + 1]]
            own, other = (a, b) if part[v] == a else (b, a)
            blk = part[nb]
            return float(np.sum(wv * (C[blk, own] - C[blk, other])))

    heap = [(-gain_of(v), v) for v in cand]
    heapq.heapify(heap)
    locked = np.zeros(g.n, dtype=bool)
    stale = np.zeros(g.n, dtype=bool)

    history: list[tuple[int, int, int, float]] = []  # (v, frm, to, gain)
    total = best = 0.0
    best_len = 0
    max_moves = max_moves or len(cand)
    while heap and len(history) < max_moves:
        neg_g, v = heapq.heappop(heap)
        if locked[v]:
            continue
        if stale[v]:
            stale[v] = False
            heapq.heappush(heap, (-gain_of(v), v))
            continue
        gain = -neg_g
        frm = int(part[v])
        to = b if frm == a else a
        w_v = 1.0 if vw is None else vw[v]
        if sizes[to] + w_v > caps[to]:
            continue
        part[v] = to
        sizes[frm] -= w_v
        sizes[to] += w_v
        locked[v] = True
        total += gain
        history.append((v, frm, to, gain))
        if total > best + 1e-9:
            best, best_len = total, len(history)
        nb = g.indices[g.indptr[v]:g.indptr[v + 1]]
        stale[nb[~locked[nb]]] = True

    # roll back past the best prefix
    for v, frm, to, _ in reversed(history[best_len:]):
        part[v] = frm
    return best


# -- driver ------------------------------------------------------------------

def refine_partition(g: Graph, part: np.ndarray, tw: np.ndarray,
                     mems: np.ndarray | None = None, eps: float = 0.03,
                     passes: int = 3, bfs_hops: int = 2,
                     pod_of: np.ndarray | None = None, lam: float = 1.0,
                     anc: np.ndarray | None = None, lams=None,
                     vw: np.ndarray | None = None,
                     objective: str = "cut",
                     speeds: np.ndarray | None = None,
                     c_comp: float = 1.0,
                     verbose: bool = False) -> np.ndarray:
    """geoRef: scheduled pairwise FM until no pass improves the objective.

    ``anc``/``lams`` switch the FM gains to the weighted tree objective
    (a cut edge costs ``lams[LCA level]``); ``pod_of``/``lam`` is the
    two-level sugar (see :func:`fm_pair_refine`).  ``vw`` makes the
    size/cap accounting weight-aware (coarse multilevel levels —
    ``tw``/``mems`` are then compared against summed vertex weights).

    ``objective="bottleneck"`` refines the makespan instead: one shared
    :class:`VolumeGainTracker` carries the per-(receiver, level)
    deduplicated volumes and per-PU modeled compute (``speeds`` /
    ``c_comp``) across all pair passes, and pairs run ordered by how hot
    their heavier endpoint is — the critical PU drains first.  Pair
    coloring is irrelevant here (the driver is host-sequential and every
    gain is global), so the schedule is just the sort.
    """
    part = np.asarray(part, dtype=np.int32).copy()
    k = len(tw)
    caps = np.ceil(np.asarray(tw) * (1.0 + eps))
    if mems is not None:
        caps = np.minimum(caps, np.floor(np.asarray(mems)))

    if objective == "bottleneck":
        t_anc = anc
        if t_anc is None and pod_of is not None:
            t_anc = np.asarray(pod_of)[None, :]
            lams = (1.0, lam)
        tracker = VolumeGainTracker(g, part, k, t_anc, lams=lams,
                                    speeds=speeds, c_comp=c_comp, vw=vw)
        for p in range(passes):
            pairs, _w = quotient_graph(g, part, k)
            if len(pairs) == 0:
                break
            totals = tracker.totals()
            heat = np.maximum(totals[pairs[:, 0]], totals[pairs[:, 1]])
            gain = 0.0
            for e in np.argsort(-heat, kind="stable"):
                gain += fm_pair_refine(g, part, int(pairs[e, 0]),
                                       int(pairs[e, 1]), caps, bfs_hops,
                                       vw=vw, objective="bottleneck",
                                       tracker=tracker)
            if verbose:
                print(f"  refine pass {p}: gain {gain:.3f} "
                      f"makespan {tracker.bottleneck():.3f}")
            if gain <= 0.0:     # epsilon gains (lexicographic-only
                break           # improvements) keep the passes coming
        return part

    for p in range(passes):
        pairs, w = quotient_graph(g, part, k)
        if len(pairs) == 0:
            break
        colors = greedy_edge_coloring(pairs, w)
        gain = 0.0
        for c in range(colors.max() + 1):
            for e in np.nonzero(colors == c)[0]:
                gain += fm_pair_refine(g, part, int(pairs[e, 0]),
                                       int(pairs[e, 1]), caps, bfs_hops,
                                       pod_of=pod_of, lam=lam,
                                       anc=anc, lams=lams, vw=vw)
        if verbose:
            print(f"  refine pass {p}: gain {gain:.0f} "
                  f"cut {edge_cut(g, part):.0f}")
        if gain <= 0:
            break
    return part


# -- per-level sweeps on the block quotient graph ----------------------------

def _quotient_weight_matrix(pairs: np.ndarray, weights: np.ndarray,
                            k: int) -> np.ndarray:
    """Symmetric (k, k) dense weight matrix from :func:`quotient_graph`
    output (zero diagonal)."""
    W = np.zeros((k, k), dtype=np.float64)
    if len(pairs):
        pairs = np.asarray(pairs, dtype=np.int64)
        W[pairs[:, 0], pairs[:, 1]] = weights
        W += W.T
    return W


def _kl_sweep(W: np.ndarray, grouping: np.ndarray, groups: np.ndarray,
              max_swaps: int) -> tuple[np.ndarray, list[tuple[int, int]]]:
    """One Kernighan–Lin swap sweep of ``grouping`` on the dense quotient
    matrix ``W``: repeatedly apply the best block swap (across two
    groups, same ``groups`` id) that reduces the crossing weight, until
    none helps.  Returns ``(refined grouping, applied swaps in order)``
    — the swap list lets callers mirror the swaps onto deeper ancestor
    rows (:func:`refine_tree_assignment`'s whole-slot trades).
    Deterministic: ties break on the smallest (x, y)."""
    grouping = np.asarray(grouping, dtype=np.int64).copy()
    k = len(grouping)
    swaps: list[tuple[int, int]] = []
    for _ in range(max_swaps):
        best_gain, best = 1e-9, None
        for x in range(k):
            for y in range(x + 1, k):
                if grouping[x] == grouping[y] or groups[x] != groups[y]:
                    continue
                mp = grouping == grouping[x]
                mq = grouping == grouping[y]
                # KL gain: D_x + D_y - 2 w(x,y); edges to third groups
                # and the x-y edge itself stay crossing either way
                d_x = W[x] @ mq - W[x] @ mp
                d_y = W[y] @ mp - W[y] @ mq
                gain = float(d_x + d_y - 2.0 * W[x, y])
                if gain > best_gain:
                    best_gain, best = gain, (x, y)
        if best is None:
            break
        x, y = best
        grouping[x], grouping[y] = grouping[y], grouping[x]
        swaps.append((x, y))
    return grouping, swaps


def refine_pod_assignment(pairs: np.ndarray, weights: np.ndarray,
                          pod_of: np.ndarray,
                          groups: np.ndarray | None = None,
                          max_swaps: int | None = None) -> np.ndarray:
    """Kernighan–Lin sweep of the block->pod grouping on the block
    quotient graph — the single-level (``h == 2``) instance of
    :func:`refine_tree_assignment`.

    ``pairs``/``weights`` are :func:`quotient_graph` output; ``pod_of``
    the starting (k,) assignment (e.g. ``Topology.pod_assignment`` —
    contiguous).  Swapping preserves the pod sizes (the hier meshes are
    rectangular), and ``groups`` (k,) restricts swaps to blocks with the
    same group id — pass the PU spec class so a fast PU's block never
    lands on a slow PU's pod slot; two blocks may trade places only when
    their PUs are interchangeable.

    Returns the refined (k,) pod assignment — the *partition-derived*
    grouping that ``sparse.distributed.build_plan_hier`` consumes as an
    explicit pod array.  The inter-pod quotient weight (= inter-pod cut)
    never increases; the flat cut is untouched (only labels regroup).
    Deterministic: ties break on the smallest (x, y).  O(k^2) candidate
    pairs per applied swap with O(k) gain evaluation — the quotient
    graph has one vertex per PU, so this is host-trivial.
    """
    pod_of = np.asarray(pod_of, dtype=np.int64)
    k = len(pod_of)
    W = _quotient_weight_matrix(pairs, weights, k)
    groups = (np.zeros(k, dtype=np.int64) if groups is None
              else np.asarray(groups))
    out, _ = _kl_sweep(W, pod_of, groups, k * k if max_swaps is None
                       else max_swaps)
    return out


def refine_tree_assignment(pairs: np.ndarray, weights: np.ndarray,
                           anc: np.ndarray,
                           groups: np.ndarray | None = None,
                           max_swaps: int | None = None) -> np.ndarray:
    """Per-level Kernighan–Lin sweep of the block ancestor table on the
    block quotient graph — the tree generalization of
    :func:`refine_pod_assignment`.

    Levels are swept top-down (coarsest grouping first — it prices the
    most expensive links): at depth ``d`` the sweep trades whole *leaf
    slots* between depth-``d`` groups, minimizing the weight crossing
    that grouping; swaps are restricted to blocks with the same
    ``groups`` id (PU spec class) *and* — below the top level — the same
    depth-``d-1`` ancestor, so every swap keeps the table nested and all
    coarser decisions intact.  Each applied swap exchanges the blocks'
    entire remaining slot paths (``anc[d:, x] <-> anc[d:, y]``), which
    is what makes the nesting invariant free.

    Returns the refined (h-1, k) ancestor table, consumable by
    ``sparse.distributed.build_plan_tree`` — per level, the crossing
    quotient weight never increases versus the input table, pod/group
    sizes are preserved, and the flat cut is untouched.
    """
    anc = np.atleast_2d(np.asarray(anc, dtype=np.int64)).copy()
    h1, k = anc.shape
    W = _quotient_weight_matrix(pairs, weights, k)
    groups = (np.zeros(k, dtype=np.int64) if groups is None
              else np.asarray(groups, dtype=np.int64))
    if max_swaps is None:
        max_swaps = k * k
    for d in range(h1):
        # below the top level, a trade must stay inside one parent group
        if d == 0:
            combo = groups
        else:
            parent = anc[d - 1]
            combo = groups * (int(parent.max()) + 1) + parent
        _, swaps = _kl_sweep(W, anc[d], combo, max_swaps)
        for x, y in swaps:                     # whole-slot trades
            anc[d:, [x, y]] = anc[d:, [y, x]]
    return anc
