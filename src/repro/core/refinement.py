"""Combinatorial local refinement (Geographer-R, Sec. V).

Pipeline per refinement pass:
  1. build the communication (quotient) graph G_c — one vertex per block,
     edge weights = communication volume between block pairs;
  2. maximum-edge-coloring-style greedy coloring of G_c to schedule
     communication rounds (color classes = sets of disjoint block pairs that
     refine concurrently — Holtgrewe/Sanders/Schulz [20] style);
  3. per pair, pairwise FM on the extended boundary neighborhood: candidates
     are vertices within ``bfs_hops`` BFS rounds of the boundary, moves are
     gain-ordered with tentative-prefix rollback (classic FM), subject to the
     heterogeneous caps  size_i <= min(m_cap_i, (1+eps) tw_i).

In the paper each PU pair runs FM independently and keeps the better of the
two solutions; here the pairs within a color class touch disjoint blocks, so
a host-sequential sweep over the class is semantically the parallel result.
"""
from __future__ import annotations

import heapq

import numpy as np

from ..sparse.graph import Graph
from .metrics import block_sizes_of, edge_cut


# -- 1. quotient graph ------------------------------------------------------

def quotient_graph(g: Graph, part: np.ndarray, k: int):
    """Block-level communication graph: returns (pairs, weights) with
    pairs (m, 2) int (a < b), weights = inter-block edge weight (cut)."""
    src, dst, w = g.edge_list()
    pa, pb = part[src], part[dst]
    ext = pa < pb
    key = pa[ext].astype(np.int64) * k + pb[ext]
    order = np.argsort(key, kind="stable")
    key_s, w_s = key[order], w[ext][order]
    uniq, start = np.unique(key_s, return_index=True)
    wsum = np.add.reduceat(w_s, start) if len(w_s) else np.zeros(0)
    pairs = np.stack([uniq // k, uniq % k], axis=1).astype(np.int32)
    return pairs, wsum


# -- 2. edge coloring -------------------------------------------------------

def greedy_edge_coloring(pairs: np.ndarray, weights: np.ndarray
                         ) -> np.ndarray:
    """Greedy edge coloring, heaviest edges first.  Returns color per edge.

    Guarantees <= 2*maxdeg - 1 colors; in practice close to maxdeg (Vizing).
    Heaviest-first means the largest communication volumes get the earliest
    rounds — matching [20]'s scheduling heuristic.
    """
    order = np.argsort(-weights, kind="stable")
    colors = -np.ones(len(pairs), dtype=np.int32)
    used: dict[int, set[int]] = {}
    for e in order:
        a, b = int(pairs[e, 0]), int(pairs[e, 1])
        ua = used.setdefault(a, set())
        ub = used.setdefault(b, set())
        c = 0
        while c in ua or c in ub:
            c += 1
        colors[e] = c
        ua.add(c)
        ub.add(c)
    return colors


# -- 3. pairwise FM ---------------------------------------------------------

def _boundary_candidates(g: Graph, part: np.ndarray, a: int, b: int,
                         bfs_hops: int, max_frac: float = 0.25
                         ) -> np.ndarray:
    """Vertices of blocks a/b within bfs_hops of the a|b boundary."""
    src, dst, _ = g.edge_list()
    on_ab = ((part[src] == a) & (part[dst] == b)) | \
            ((part[src] == b) & (part[dst] == a))
    frontier = np.unique(np.concatenate([src[on_ab], dst[on_ab]]))
    seen = np.zeros(g.n, dtype=bool)
    seen[frontier] = True
    in_pair = (part == a) | (part == b)
    for _ in range(bfs_hops):
        if len(frontier) == 0:
            break
        nbrs = []
        for v in frontier:
            nbrs.append(g.indices[g.indptr[v]:g.indptr[v + 1]])
        nxt = np.unique(np.concatenate(nbrs)) if nbrs else np.zeros(0, int)
        nxt = nxt[in_pair[nxt] & ~seen[nxt]]
        seen[nxt] = True
        frontier = nxt
    cand = np.nonzero(seen & in_pair)[0]
    # paper: "we do not consider all vertices but only a smaller number"
    cap = max(64, int(max_frac * in_pair.sum()))
    return cand[:cap]


def fm_pair_refine(g: Graph, part: np.ndarray, a: int, b: int,
                   caps: np.ndarray, bfs_hops: int = 2,
                   max_moves: int | None = None) -> float:
    """One FM pass between blocks a and b.  Mutates ``part``.

    Returns the achieved cut gain (>= 0; rolls back to the best prefix).
    """
    cand = _boundary_candidates(g, part, a, b, bfs_hops)
    if len(cand) == 0:
        return 0.0
    sizes = block_sizes_of(part, len(caps)).astype(np.int64)

    def gain_of(v: int) -> float:
        nb = g.indices[g.indptr[v]:g.indptr[v + 1]]
        wv = g.weights[g.indptr[v]:g.indptr[v + 1]]
        own, other = (a, b) if part[v] == a else (b, a)
        return float(np.sum(wv * (part[nb] == other))
                     - np.sum(wv * (part[nb] == own)))

    heap = [(-gain_of(v), v) for v in cand]
    heapq.heapify(heap)
    locked = np.zeros(g.n, dtype=bool)
    stale = np.zeros(g.n, dtype=bool)

    history: list[tuple[int, int, int, float]] = []  # (v, frm, to, gain)
    total = best = 0.0
    best_len = 0
    max_moves = max_moves or len(cand)
    while heap and len(history) < max_moves:
        neg_g, v = heapq.heappop(heap)
        if locked[v]:
            continue
        if stale[v]:
            stale[v] = False
            heapq.heappush(heap, (-gain_of(v), v))
            continue
        gain = -neg_g
        frm = int(part[v])
        to = b if frm == a else a
        if sizes[to] + 1 > caps[to]:
            continue
        part[v] = to
        sizes[frm] -= 1
        sizes[to] += 1
        locked[v] = True
        total += gain
        history.append((v, frm, to, gain))
        if total > best + 1e-9:
            best, best_len = total, len(history)
        nb = g.indices[g.indptr[v]:g.indptr[v + 1]]
        stale[nb[~locked[nb]]] = True

    # roll back past the best prefix
    for v, frm, to, _ in reversed(history[best_len:]):
        part[v] = frm
    return best


# -- driver ------------------------------------------------------------------

def refine_partition(g: Graph, part: np.ndarray, tw: np.ndarray,
                     mems: np.ndarray | None = None, eps: float = 0.03,
                     passes: int = 3, bfs_hops: int = 2,
                     verbose: bool = False) -> np.ndarray:
    """geoRef: scheduled pairwise FM until no pass improves the cut."""
    part = np.asarray(part, dtype=np.int32).copy()
    k = len(tw)
    caps = np.ceil(np.asarray(tw) * (1.0 + eps))
    if mems is not None:
        caps = np.minimum(caps, np.floor(np.asarray(mems)))
    for p in range(passes):
        pairs, w = quotient_graph(g, part, k)
        if len(pairs) == 0:
            break
        colors = greedy_edge_coloring(pairs, w)
        gain = 0.0
        for c in range(colors.max() + 1):
            for e in np.nonzero(colors == c)[0]:
                gain += fm_pair_refine(g, part, int(pairs[e, 0]),
                                       int(pairs[e, 1]), caps, bfs_hops)
        if verbose:
            print(f"  refine pass {p}: gain {gain:.0f} "
                  f"cut {edge_cut(g, part):.0f}")
        if gain <= 0:
            break
    return part
