"""Unified partitioning API — the black-box phase-2 interface of the paper.

``partition(graph, topology, method)`` runs the two-stage LDHT pipeline:
  stage 1: Algorithm 1 -> target block sizes tw (optimal for Eq. 2 + 3);
  stage 2: the chosen partitioner minimizes the cut (Eq. 1) under tw.

Methods (paper nomenclature):
  geoKM    — balanced k-means                      (Geographer)
  geoRef   — geoKM + multilevel pairwise-FM        (Geographer-R)
  geoHier  — hierarchical balanced k-means + refinement (Sec. V)
  sfc      — Morton space-filling curve            (zSFC analogue)
  rcb      — recursive coordinate bisection        (zRCB analogue)
  rib      — recursive inertial bisection          (zRIB analogue)
  sfcRef   — sfc + multilevel FM refinement        (ParMetisGeom-like:
             geometric initial partition + combinatorial refinement)
  greedyRef— BFS-greedy growing + multilevel FM    (ParMetisGraph-like:
             combinatorial initial partition + combinatorial refinement)

Tree-aware mode (``pods=`` / ``tree=`` / ``fanouts=``): the flat
objective (Eq. 1) ignores that on a hierarchical machine each cut edge
pays the link latency of its LCA level (``sparse.distributed``
``comm='hier'``).  :func:`partition_tree` runs the whole pipeline
recursively down the ``fanouts`` tree, WindGP-style: at every level the
load is water-filled over the subtree aggregates (the tree-aware
Algorithm 1 — no stage-B rescale) and the graph is partitioned at that
granularity, minimizing the future level-crossing cut directly; a
per-level KL sweep then regroups equal-spec blocks on the quotient graph
(``refinement.refine_tree_assignment``) and a weighted FM pass refines
against the tree objective (a cut edge costs ``lams[LCA level]``,
``topology.LinkCosts``).  :func:`partition_hier` is the two-level
(``pods=``) instance, bit-identical to the PR 4 pod pipeline at the
refinement stages.  The returned :class:`HierPartition` carries the full
ancestor table the tree runtime consumes directly
(``make_operator(..., part=hier_partition)``).
"""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from ..sparse.graph import Graph
from .balanced_kmeans import (partition_balanced_kmeans,
                              partition_hierarchical_kmeans)
from .block_sizes import target_block_sizes, waterfill
from .metrics import summarize, summarize_hier, summarize_tree
from .multilevel import partition_multilevel_refine
from .rcb import partition_rcb
from .refinement import (quotient_graph, refine_partition,
                         refine_pod_assignment, refine_tree_assignment)
from .rib import partition_rib
from .sfc import partition_sfc
from .topology import Topology, normalize_pod_of, normalize_tree_of


def _greedy_growing(g: Graph, tw: np.ndarray, seed: int = 0) -> np.ndarray:
    """Combinatorial initial partition: multi-source BFS region growing with
    heterogeneous capacities (GGP — the classic Metis-style initializer).

    Blocks with a zero rounded target get no seed and receive no orphans
    — on fully saturated topologies a zero-target block must stay empty,
    not grab a seed vertex another block needs."""
    rng = np.random.default_rng(seed)
    k = len(tw)
    want = np.round(tw).astype(np.int64)
    want[np.argmax(want)] += g.n - want.sum()
    part = -np.ones(g.n, dtype=np.int32)
    active = np.flatnonzero(want > 0)
    # seeds: spread via random picks (BFS-farthest would be better; this is
    # the baseline tool, quality is allowed to be baseline-ish)
    seeds = np.full(k, -1, dtype=np.int64)
    seeds[active] = rng.choice(g.n, size=len(active), replace=False)
    from collections import deque
    queues = [deque([int(seeds[b])] if seeds[b] >= 0 else [])
              for b in range(k)]
    sizes = np.zeros(k, dtype=np.int64)
    for b in active:
        s = seeds[b]
        if part[s] == -1:
            part[s] = b
            sizes[b] += 1
    active_mask = want > 0
    while True:
        progressed_any = False
        for b in np.argsort(sizes / np.maximum(want, 1)):
            if sizes[b] >= want[b] or not queues[b]:
                continue
            progressed = False
            while queues[b] and not progressed:
                v = queues[b].popleft()
                for u in g.indices[g.indptr[v]:g.indptr[v + 1]]:
                    if part[u] == -1 and sizes[b] < want[b]:
                        part[u] = b
                        sizes[b] += 1
                        queues[b].append(int(u))
                        progressed = True
            progressed_any = progressed_any or progressed
        if not progressed_any:
            break
    # orphans (disconnected leftovers): most underloaded *active* block —
    # never a zero-target one
    for v in np.nonzero(part == -1)[0]:
        ratio = np.where(active_mask, sizes / np.maximum(want, 1), np.inf)
        b = int(np.argmin(ratio))
        part[v] = b
        sizes[b] += 1
    return part


def _dispatch(g: Graph, method: str, tw: np.ndarray, mems: np.ndarray,
              fanouts: tuple[int, ...], seed: int, eps: float,
              **kw) -> np.ndarray:
    """Stage-2 method dispatch shared by the flat and hierarchical
    pipelines; ``tw``/``mems``/``fanouts`` describe whatever block level
    is being partitioned (PUs, or pods for the hier top level)."""
    if method == "geoKM":
        part = partition_balanced_kmeans(g, tw, seed=seed, **kw)
    elif method == "geoRef":
        part = partition_balanced_kmeans(g, tw, seed=seed, **kw)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps,
                                           seed=seed)
    elif method == "geoHier":
        part = partition_hierarchical_kmeans(g, tw, fanouts, seed=seed, **kw)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps,
                                           seed=seed)
    elif method == "sfc":
        part = partition_sfc(g, tw, seed=seed)
    elif method == "rcb":
        part = partition_rcb(g, tw, seed=seed)
    elif method == "rib":
        part = partition_rib(g, tw, seed=seed)
    elif method == "sfcRef":
        part = partition_sfc(g, tw, seed=seed)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps,
                                           seed=seed)
    elif method == "greedyRef":
        part = _greedy_growing(g, tw, seed=seed)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps,
                                           seed=seed)
    else:
        raise ValueError(f"unknown method {method!r}")
    return np.asarray(part, dtype=np.int32)


def partition(g: Graph, topo: Topology, method: str = "geoRef",
              tw: np.ndarray | None = None, seed: int = 0,
              eps: float = 0.03, pods=None, lam: float | None = None,
              fanouts=None, tree=None, lams=None, objective: str = "cut",
              **kw) -> tuple[np.ndarray, np.ndarray]:
    """Two-stage LDHT solve.  Returns (part, tw).

    With ``pods`` (pod count or explicit (k,) pod-of-PU array) the
    pipeline runs hierarchically via :func:`partition_hier`; with
    ``fanouts``/``tree`` it runs the arbitrary-depth recursion
    (:func:`partition_tree`).  Use those functions directly when you
    also need the resulting ancestor table (e.g. to feed
    ``sparse.distributed.build_plan_tree``).

    ``objective="bottleneck"`` appends a makespan refinement stage
    (:func:`core.refinement.refine_partition` bottleneck mode — max over
    PUs of modeled compute + weighted deduplicated receive volume,
    ``core.costmodel.BottleneckCost``); ``"cut"`` (default) is the
    summed lambda-cut pipeline, bit-identical to before the objective
    became selectable."""
    if pods is not None:
        res = partition_hier(g, topo, method, pods=pods, tw=tw, seed=seed,
                             eps=eps, lam=lam, objective=objective, **kw)
        return res.part, res.tw
    if fanouts is not None or tree is not None:
        res = partition_tree(g, topo, method, fanouts=fanouts, tree=tree,
                             tw=tw, seed=seed, eps=eps, lams=lams,
                             objective=objective, **kw)
        return res.part, res.tw
    if tw is None:
        tw = target_block_sizes(g.n, topo)
    part = _dispatch(g, method, tw, topo.memories, topo.fanouts, seed, eps,
                     **kw)
    if objective == "bottleneck":
        part = refine_partition(g, part, tw, mems=topo.memories, eps=eps,
                                objective="bottleneck", speeds=topo.speeds)
    elif objective != "cut":
        raise ValueError(f"unknown objective {objective!r}")
    return part, tw


@dataclasses.dataclass
class HierPartition:
    """Tree-aware pipeline output: the partition *and* the co-optimized
    ancestor table that the tree runtime consumes.

    ``anc`` is the (h-1, k) ancestor table (``topology.normalize_tree_of``
    form); ``pod_of``/``lam`` are its two-level views (top grouping and
    outermost/innermost weight ratio), kept as the PR 4 pod API.  After
    the per-level sweep the table need not be contiguous —
    ``sparse.distributed.build_plan_tree`` relabels blocks tree-major
    internally (``block_map``), and ``sparse.make_operator(...,
    backend='dist_hier', part=<this>)`` unpacks everything directly.
    """

    part: np.ndarray        # (n,) vertex -> block (= PU)
    tw: np.ndarray          # (k,) Algorithm-1 targets, PU order
    pod_of: np.ndarray      # (k,) block -> top-level group (pod)
    lam: float              # outer/inner link-cost ratio of the objective
    anc: np.ndarray = None  # (h-1, k) ancestor table; pod_of == anc[0]
    lams: tuple = None      # (h,) per-level objective weights
    fanouts: tuple = ()     # (k_1, ..., k_h) of the partitioned tree
    objective: str = "cut"  # which cost model refinement minimized

    def __post_init__(self):
        if self.anc is None:
            self.anc = np.asarray(self.pod_of)[None, :]
        self.anc = np.asarray(self.anc)
        if not self.fanouts:
            self.fanouts = _infer_fanouts(self.anc, self.k)
        if self.lams is None:
            # geometric ladder from 1 to lam across the table's depth —
            # (1, lam) at h == 2, consistent with the anc depth so the
            # tree metrics accept (lams, anc) pairs straight off this
            h = len(self.fanouts)
            self.lams = ((1.0,) if h <= 1 else
                         tuple(float(self.lam) ** (l / (h - 1))
                               for l in range(h)))

    @property
    def k(self) -> int:
        return len(self.tw)

    @property
    def h(self) -> int:
        return len(self.fanouts)

    @property
    def n_pods(self) -> int:
        return int(self.pod_of.max()) + 1


def _spec_groups(topo: Topology) -> np.ndarray:
    """(k,) group id per PU: PUs are interchangeable (their blocks may
    trade pod slots) iff they share (speed, memory)."""
    spec = np.stack([topo.speeds, topo.memories], axis=1)
    _, groups = np.unique(spec, axis=0, return_inverse=True)
    return groups


def pod_assignment_for(g: Graph, part: np.ndarray, topo: Topology,
                       pods) -> np.ndarray:
    """Partition-derived pod assignment for an existing (flat) partition:
    start from ``Topology.pod_assignment`` and KL-sweep equal-spec blocks
    on the quotient graph (``refinement.refine_pod_assignment``) so the
    heaviest block pairs share pods.  The inter-pod cut never increases
    versus the contiguous grouping; feed the result to
    ``build_plan_hier``/``make_operator`` as the explicit pod array."""
    pod_of = normalize_pod_of(pods, topo.k)
    pairs, w = quotient_graph(g, np.asarray(part, dtype=np.int32), topo.k)
    return refine_pod_assignment(pairs, w, pod_of,
                                 groups=_spec_groups(topo))


def tree_assignment_for(g: Graph, part: np.ndarray, topo: Topology,
                        tree=None, fanouts=None) -> np.ndarray:
    """Partition-derived ancestor table for an existing (flat) partition
    — the tree generalization of :func:`pod_assignment_for`: start from
    the canonical nested grouping and sweep equal-spec blocks level by
    level (``refinement.refine_tree_assignment``) so the heaviest block
    pairs meet at the deepest (cheapest) tree level.  Feed the result to
    ``build_plan_tree``/``make_operator`` as the explicit table."""
    anc = normalize_tree_of(tree, topo.k,
                            fanouts if (fanouts is not None or
                                        tree is not None)
                            else topo.fanouts)
    pairs, w = quotient_graph(g, np.asarray(part, dtype=np.int32), topo.k)
    return refine_tree_assignment(pairs, w, anc, groups=_spec_groups(topo))


def _infer_fanouts(anc: np.ndarray, k: int) -> tuple[int, ...]:
    """(k_1, ..., k_h) implied by a validated nested ancestor table."""
    counts = [int(np.asarray(row).max()) + 1 for row in anc] + [k]
    prev = 1
    fanouts = []
    for c in counts:
        fanouts.append(c // prev)
        prev = c
    return tuple(fanouts)


def _maybe_verify_partition(res: "HierPartition", n: int,
                            validate: bool | None) -> "HierPartition":
    """Structural verification of a partition result (``repro.analysis``
    PART0xx).  ``validate=None`` defers to ``REPRO_VALIDATE`` (on by
    default in the test suite via conftest)."""
    if validate is None:
        validate = os.environ.get("REPRO_VALIDATE", "0") not in ("", "0")
    if validate:
        from ..analysis import verify_partition  # lazy: keep import acyclic
        verify_partition(res, n).raise_for_errors()
    return res


def partition_tree(g: Graph, topo: Topology, method: str = "geoRef",
                   fanouts=None, tree=None, tw: np.ndarray | None = None,
                   seed: int = 0, eps: float = 0.03, lams=None,
                   refine: bool = True, validate: bool | None = None,
                   objective: str = "cut", c_comp: float = 1.0,
                   **kw) -> HierPartition:
    """Tree-aware recursive pipeline (the tentpole of the tree runtime):

      A. the load is water-filled over the current level's subtree
         aggregates (tree-aware Algorithm 1: summed speeds under summed
         memories — ``block_sizes.waterfill``) and the graph is
         partitioned at that granularity with the chosen method — the
         future level-crossing cut is minimized directly;
      B. recursion: each subtree's subgraph is partitioned among its
         children the same way, down to the leaves — the realized
         subtree load is water-filled over the children, so a saturated
         member's overflow is absorbed by its siblings (no stage-B
         rescale);
      C. a per-level KL sweep regroups equal-spec blocks on the quotient
         graph (``refinement.refine_tree_assignment``) — the
         partition-derived ancestor table;
      D. scheduled pairwise FM refines against the weighted tree
         objective (a cut edge costs ``lams[LCA level]``).

    ``tree`` accepts anything ``topology.normalize_tree_of`` does (pod
    count, pod array, ancestor table); default is the canonical table of
    ``fanouts`` (default ``topo.fanouts``).  ``lams`` defaults to the
    topology's link-cost ladder (``topo.link_costs(levels=h).lams``).
    At depth 2 every stage is the PR 4 pod pipeline (stages C/D
    bit-identical; stages A/B replace the target rescale with the
    per-subtree water-fill).

    ``objective="bottleneck"`` adds a stage E after the (unchanged) cut
    FM: makespan refinement over the incremental volume-gain tracker
    (``refinement.refine_partition(objective='bottleneck')``,
    Algorithm-1 ``topo.speeds`` as the compute model; ``c_comp`` is the
    modeled compute cost per weight unit in halo-word units —
    ``core.costmodel.CostModel.c_comp``) — the critical PU sheds
    load/halo first.  ``"cut"`` leaves the pipeline bit-identical to
    before the objective became selectable.
    """
    if objective not in ("cut", "bottleneck"):
        raise ValueError(f"unknown objective {objective!r}")
    if tw is not None:
        tw = np.asarray(tw, dtype=np.float64)
    anc = normalize_tree_of(tree, topo.k,
                            fanouts if (fanouts is not None or
                                        tree is not None)
                            else topo.fanouts)
    h0 = anc.shape[0] + 1
    # drop trivial levels: a row that does not strictly refine the one
    # above (fanout 1) or that already separates every leaf (identity —
    # its boundary coincides with the leaf level) adds no block pairs
    kept, prev = [], 1
    for t in range(anc.shape[0]):
        c = int(anc[t].max()) + 1
        if prev < c < topo.k:
            kept.append(t)
            prev = c
    anc = anc[kept]
    fanouts = _infer_fanouts(anc, topo.k)
    h = len(fanouts)
    if lams is None:
        lams = tuple(topo.link_costs(levels=max(h, 2)).lams[:h])
    else:
        lams = tuple(float(x) for x in np.atleast_1d(lams))
        if len(lams) == h0 and h != h0:
            # keep the weights of the surviving levels (row t prices
            # level h0-1-t; the leaf level keeps lams[0])
            lams = tuple([lams[0]] + [lams[h0 - 1 - t]
                                      for t in reversed(kept)])
        elif len(lams) != h:
            raise ValueError(f"need {h} per-level weights for the "
                             f"{fanouts} tree, got {len(lams)}")
    lam = lams[-1] / lams[0]

    if anc.shape[0] == 0:                    # flat tree: no boundary to price
        if tw is None:
            tw = target_block_sizes(g.n, topo)
        part = _dispatch(g, method, tw, topo.memories, topo.fanouts, seed,
                         eps, **kw)
        if refine and objective == "bottleneck":
            part = refine_partition(g, part, tw, mems=topo.memories,
                                    eps=eps, objective="bottleneck",
                                    speeds=topo.speeds, c_comp=c_comp)
        return _maybe_verify_partition(
            HierPartition(part=part, tw=tw,
                          pod_of=np.zeros(topo.k, dtype=np.int64),
                          lam=lam, anc=np.zeros((0, topo.k), np.int64),
                          lams=(lams[0],), fanouts=(topo.k,),
                          objective=objective),
            g.n, validate)

    # A/B. recurse down the tree: water-fill the level's aggregates, then
    # partition at that granularity and descend into each subtree
    speeds, mems = topo.speeds, topo.memories
    wleaf = speeds if tw is None else tw     # water-fill preference weights
    part = np.empty(g.n, dtype=np.int32)
    tw_out = np.zeros(topo.k, dtype=np.float64)

    def rec(sub: Graph, ids: np.ndarray, pus: np.ndarray,
            anc_sub: np.ndarray, seed_l: int) -> None:
        if len(pus) == 1:
            part[ids] = pus[0]
            tw_out[pus[0]] = sub.n
            return
        if anc_sub.shape[0] == 0:            # leaf level: PUs directly
            tw_p = waterfill(sub.n, wleaf[pus], mems[pus], strict=False)
            tw_out[pus] = tw_p
            sub_part = _dispatch(sub, method, tw_p, mems[pus],
                                 (len(pus),), seed_l, eps, **kw)
            part[ids] = pus[sub_part]
            return
        top = anc_sub[0]
        gids = np.unique(top)
        wg = np.array([wleaf[pus[top == gi]].sum() for gi in gids])
        cg = np.array([mems[pus[top == gi]].sum() for gi in gids])
        tw_g = waterfill(sub.n, wg, cg, strict=False)
        vgrp = _dispatch(sub, method, tw_g, cg, (len(gids),), seed_l, eps,
                         **kw)
        for i, gi in enumerate(gids):
            mask = vgrp == i
            if not mask.any():
                continue
            ss, sids = sub.subgraph(mask)
            rec(ss, ids[sids], pus[top == gi], anc_sub[1:, top == gi],
                seed_l + i + 1)

    rec(g, np.arange(g.n), np.arange(topo.k), anc, seed)
    tw = tw_out if tw is None else tw

    # C. per-level sweep: co-optimize the ancestor table with the
    # realized partition (equal-spec blocks may trade slots)
    if refine:
        pairs, w = quotient_graph(g, part, topo.k)
        anc = refine_tree_assignment(pairs, w, anc,
                                     groups=_spec_groups(topo))
        # D. vertex-level FM against the weighted tree objective
        part = refine_partition(g, part, tw, mems=mems, eps=eps,
                                anc=anc, lams=lams)
        # E. (bottleneck mode) makespan polish from the cut-refined
        # start: drain modeled compute + dedup halo off the critical PU
        if objective == "bottleneck":
            part = refine_partition(g, part, tw, mems=mems, eps=eps,
                                    anc=anc, lams=lams,
                                    objective="bottleneck", speeds=speeds,
                                    c_comp=c_comp)
    return _maybe_verify_partition(
        HierPartition(part=part, tw=tw, pod_of=anc[0], lam=lam,
                      anc=anc, lams=lams, fanouts=fanouts,
                      objective=objective), g.n, validate)


def partition_hier(g: Graph, topo: Topology, method: str = "geoRef",
                   pods=2, tw: np.ndarray | None = None, seed: int = 0,
                   eps: float = 0.03, lam: float | None = None,
                   refine: bool = True, objective: str = "cut",
                   **kw) -> HierPartition:
    """Pod-aware two-level pipeline — the ``h == 2`` instance of
    :func:`partition_tree` (``pods`` = pod count or explicit (k,) pod
    array; stages C/D are bit-identical to the PR 4 pod path, stages A/B
    water-fill per subtree instead of rescaling the global targets).

    ``lam`` defaults to the topology's link-cost ratio
    (``topo.link_costs().lam`` — the hier round-latency model).
    """
    if lam is None:
        lam = topo.link_costs().lam
    pod_of = normalize_pod_of(pods, topo.k)
    res = partition_tree(g, topo, method, tree=pod_of[None, :], tw=tw,
                         seed=seed, eps=eps, lams=(1.0, float(lam)),
                         refine=refine, objective=objective, **kw)
    if res.anc.shape[0] == 0:                # pods == 1 degenerates
        return HierPartition(part=res.part, tw=res.tw, pod_of=pod_of,
                             lam=lam, objective=objective)
    return res


METHODS = ("geoKM", "geoRef", "geoHier", "sfc", "rcb", "rib", "sfcRef",
           "greedyRef")


def evaluate(g: Graph, topo: Topology, methods=METHODS, seed: int = 0,
             pods=None, lam: float | None = None, fanouts=None,
             tree=None, lams=None, objective: str = "cut",
             verbose: bool = True) -> dict[str, dict]:
    """Run all methods; return {method: metrics+time} (Table IV analogue).

    With ``pods`` each method runs the pod-aware pipeline
    (:func:`partition_hier`) and the metrics include the intra/inter-pod
    split plus the weighted two-level objective; with ``fanouts``/
    ``tree`` the arbitrary-depth pipeline (:func:`partition_tree`) with
    per-level splits and the tree objective.  ``objective`` selects the
    refinement cost model per method (the summaries always report both
    the summed cut and the bottleneck makespan)."""
    out = {}
    tw = target_block_sizes(g.n, topo)
    tree_mode = fanouts is not None or tree is not None
    for m in methods:
        t0 = time.perf_counter()
        if pods is not None:
            res = partition_hier(g, topo, m, pods=pods, tw=tw, seed=seed,
                                 lam=lam, objective=objective)
            part = res.part
            s = summarize_hier(g, part, topo, tw, res.pod_of, lam=res.lam)
        elif tree_mode:
            res = partition_tree(g, topo, m, fanouts=fanouts, tree=tree,
                                 tw=tw, seed=seed, lams=lams,
                                 objective=objective)
            part = res.part
            s = summarize_tree(g, part, topo, tw, res.anc, lams=res.lams)
        else:
            part, _ = partition(g, topo, m, tw=tw, seed=seed,
                                objective=objective)
            s = summarize(g, part, topo, tw)
        dt = time.perf_counter() - t0
        s["time_s"] = dt
        out[m] = s
        if verbose:
            line = (f"  {m:10s} cut={s['cut']:9.0f}"
                    f" maxCV={s['max_comm_volume']:6d}"
                    f" imb={s['imbalance']:.3f}"
                    f" memViol={s['mem_violations']}")
            if pods is not None:
                line += (f" interCV={s['comm_volume_inter']:6d}"
                         f" obj={s['two_level_objective']:9.0f}")
            elif tree_mode:
                line += (f" outerCV={s['comm_volume_by_level'][-1]:6d}"
                         f" obj={s['tree_objective']:9.0f}")
            print(line + f" t={dt:6.2f}s")
    return out
