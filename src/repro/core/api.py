"""Unified partitioning API — the black-box phase-2 interface of the paper.

``partition(graph, topology, method)`` runs the two-stage LDHT pipeline:
  stage 1: Algorithm 1 -> target block sizes tw (optimal for Eq. 2 + 3);
  stage 2: the chosen partitioner minimizes the cut (Eq. 1) under tw.

Methods (paper nomenclature):
  geoKM    — balanced k-means                      (Geographer)
  geoRef   — geoKM + multilevel pairwise-FM        (Geographer-R)
  geoHier  — hierarchical balanced k-means + refinement (Sec. V)
  sfc      — Morton space-filling curve            (zSFC analogue)
  rcb      — recursive coordinate bisection        (zRCB analogue)
  rib      — recursive inertial bisection          (zRIB analogue)
  sfcRef   — sfc + multilevel FM refinement        (ParMetisGeom-like:
             geometric initial partition + combinatorial refinement)
  greedyRef— BFS-greedy growing + multilevel FM    (ParMetisGraph-like:
             combinatorial initial partition + combinatorial refinement)

Pod-aware mode (``pods=``): the flat objective (Eq. 1) ignores that on a
multi-pod machine only the *inter-pod* cut pays slow-link latency
(``sparse.distributed`` ``comm='hier'``).  :func:`partition_hier` runs
the whole pipeline hierarchically, WindGP-style: Algorithm-1 targets are
aggregated per pod (``Topology.pod_aggregate``), the graph is first
partitioned into pods (minimizing the future inter-pod cut directly),
then within each pod into its PUs, then a pod-level sweep regroups
equal-spec blocks on the quotient graph and a weighted FM pass refines
against the two-level objective (inter-pod edges cost lambda-x intra,
``topology.LinkCosts``).  The returned :class:`HierPartition` carries the
pod assignment the hier runtime consumes directly
(``make_operator(..., part=hier_partition)``).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..sparse.graph import Graph
from .balanced_kmeans import (partition_balanced_kmeans,
                              partition_hierarchical_kmeans)
from .block_sizes import target_block_sizes
from .metrics import summarize, summarize_hier
from .multilevel import partition_multilevel_refine
from .rcb import partition_rcb
from .refinement import (quotient_graph, refine_partition,
                         refine_pod_assignment)
from .rib import partition_rib
from .sfc import partition_sfc
from .topology import Topology, normalize_pod_of


def _greedy_growing(g: Graph, tw: np.ndarray, seed: int = 0) -> np.ndarray:
    """Combinatorial initial partition: multi-source BFS region growing with
    heterogeneous capacities (GGP — the classic Metis-style initializer).

    Blocks with a zero rounded target get no seed and receive no orphans
    — on fully saturated topologies a zero-target block must stay empty,
    not grab a seed vertex another block needs."""
    rng = np.random.default_rng(seed)
    k = len(tw)
    want = np.round(tw).astype(np.int64)
    want[np.argmax(want)] += g.n - want.sum()
    part = -np.ones(g.n, dtype=np.int32)
    active = np.flatnonzero(want > 0)
    # seeds: spread via random picks (BFS-farthest would be better; this is
    # the baseline tool, quality is allowed to be baseline-ish)
    seeds = np.full(k, -1, dtype=np.int64)
    seeds[active] = rng.choice(g.n, size=len(active), replace=False)
    from collections import deque
    queues = [deque([int(seeds[b])] if seeds[b] >= 0 else [])
              for b in range(k)]
    sizes = np.zeros(k, dtype=np.int64)
    for b in active:
        s = seeds[b]
        if part[s] == -1:
            part[s] = b
            sizes[b] += 1
    active_mask = want > 0
    while True:
        progressed_any = False
        for b in np.argsort(sizes / np.maximum(want, 1)):
            if sizes[b] >= want[b] or not queues[b]:
                continue
            progressed = False
            while queues[b] and not progressed:
                v = queues[b].popleft()
                for u in g.indices[g.indptr[v]:g.indptr[v + 1]]:
                    if part[u] == -1 and sizes[b] < want[b]:
                        part[u] = b
                        sizes[b] += 1
                        queues[b].append(int(u))
                        progressed = True
            progressed_any = progressed_any or progressed
        if not progressed_any:
            break
    # orphans (disconnected leftovers): most underloaded *active* block —
    # never a zero-target one
    for v in np.nonzero(part == -1)[0]:
        ratio = np.where(active_mask, sizes / np.maximum(want, 1), np.inf)
        b = int(np.argmin(ratio))
        part[v] = b
        sizes[b] += 1
    return part


def _dispatch(g: Graph, method: str, tw: np.ndarray, mems: np.ndarray,
              fanouts: tuple[int, ...], seed: int, eps: float,
              **kw) -> np.ndarray:
    """Stage-2 method dispatch shared by the flat and hierarchical
    pipelines; ``tw``/``mems``/``fanouts`` describe whatever block level
    is being partitioned (PUs, or pods for the hier top level)."""
    if method == "geoKM":
        part = partition_balanced_kmeans(g, tw, seed=seed, **kw)
    elif method == "geoRef":
        part = partition_balanced_kmeans(g, tw, seed=seed, **kw)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps,
                                           seed=seed)
    elif method == "geoHier":
        part = partition_hierarchical_kmeans(g, tw, fanouts, seed=seed, **kw)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps,
                                           seed=seed)
    elif method == "sfc":
        part = partition_sfc(g, tw, seed=seed)
    elif method == "rcb":
        part = partition_rcb(g, tw, seed=seed)
    elif method == "rib":
        part = partition_rib(g, tw, seed=seed)
    elif method == "sfcRef":
        part = partition_sfc(g, tw, seed=seed)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps,
                                           seed=seed)
    elif method == "greedyRef":
        part = _greedy_growing(g, tw, seed=seed)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps,
                                           seed=seed)
    else:
        raise ValueError(f"unknown method {method!r}")
    return np.asarray(part, dtype=np.int32)


def partition(g: Graph, topo: Topology, method: str = "geoRef",
              tw: np.ndarray | None = None, seed: int = 0,
              eps: float = 0.03, pods=None, lam: float | None = None,
              **kw) -> tuple[np.ndarray, np.ndarray]:
    """Two-stage LDHT solve.  Returns (part, tw).

    With ``pods`` (pod count or explicit (k,) pod-of-PU array) the
    pipeline runs hierarchically via :func:`partition_hier`; use that
    function directly when you also need the resulting pod assignment
    (e.g. to feed ``sparse.distributed.build_plan_hier``)."""
    if pods is not None:
        res = partition_hier(g, topo, method, pods=pods, tw=tw, seed=seed,
                             eps=eps, lam=lam, **kw)
        return res.part, res.tw
    if tw is None:
        tw = target_block_sizes(g.n, topo)
    part = _dispatch(g, method, tw, topo.memories, topo.fanouts, seed, eps,
                     **kw)
    return part, tw


@dataclasses.dataclass
class HierPartition:
    """Pod-aware pipeline output: the partition *and* the co-optimized
    pod assignment that the hier runtime consumes.

    ``pod_of[b]`` is the pod of block b.  After the pod-level sweep it
    need not be contiguous — ``sparse.distributed.build_plan_hier``
    relabels blocks pod-major internally (``block_map``), and
    ``sparse.make_operator(..., backend='dist_hier', part=<this>)``
    unpacks everything directly.
    """

    part: np.ndarray        # (n,) vertex -> block (= PU)
    tw: np.ndarray          # (k,) Algorithm-1 targets, PU order
    pod_of: np.ndarray      # (k,) block -> pod
    lam: float              # inter/intra link-cost ratio of the objective

    @property
    def k(self) -> int:
        return len(self.tw)

    @property
    def n_pods(self) -> int:
        return int(self.pod_of.max()) + 1


def _spec_groups(topo: Topology) -> np.ndarray:
    """(k,) group id per PU: PUs are interchangeable (their blocks may
    trade pod slots) iff they share (speed, memory)."""
    spec = np.stack([topo.speeds, topo.memories], axis=1)
    _, groups = np.unique(spec, axis=0, return_inverse=True)
    return groups


def pod_assignment_for(g: Graph, part: np.ndarray, topo: Topology,
                       pods) -> np.ndarray:
    """Partition-derived pod assignment for an existing (flat) partition:
    start from ``Topology.pod_assignment`` and KL-sweep equal-spec blocks
    on the quotient graph (``refinement.refine_pod_assignment``) so the
    heaviest block pairs share pods.  The inter-pod cut never increases
    versus the contiguous grouping; feed the result to
    ``build_plan_hier``/``make_operator`` as the explicit pod array."""
    pod_of = normalize_pod_of(pods, topo.k)
    pairs, w = quotient_graph(g, np.asarray(part, dtype=np.int32), topo.k)
    return refine_pod_assignment(pairs, w, pod_of,
                                 groups=_spec_groups(topo))


def partition_hier(g: Graph, topo: Topology, method: str = "geoRef",
                   pods=2, tw: np.ndarray | None = None, seed: int = 0,
                   eps: float = 0.03, lam: float | None = None,
                   refine: bool = True, **kw) -> HierPartition:
    """Pod-aware two-level pipeline (the tentpole of the hier runtime):

      A. Algorithm-1 targets are aggregated per pod
         (``Topology.pod_aggregate``) and the graph is partitioned into
         *pods* with the chosen method — the future inter-pod cut is
         minimized directly, at the pod-level granularity;
      B. each pod's subgraph is partitioned into its PUs with the leaf
         targets (rescaled to the realized pod sizes);
      C. a pod-level KL sweep regroups equal-spec blocks on the quotient
         graph (``refinement.refine_pod_assignment``) — the
         partition-derived pod assignment;
      D. scheduled pairwise FM refines against the weighted two-level
         objective (inter-pod edges cost ``lam``-x intra ones).

    ``lam`` defaults to the topology's link-cost ratio
    (``topo.link_costs().lam`` — the hier round-latency model).
    """
    if tw is None:
        tw = target_block_sizes(g.n, topo)
    tw = np.asarray(tw, dtype=np.float64)
    if lam is None:
        lam = topo.link_costs().lam
    pod_of = normalize_pod_of(pods, topo.k)
    n_pods = int(pod_of.max()) + 1
    if n_pods == 1:
        part = _dispatch(g, method, tw, topo.memories, topo.fanouts, seed,
                         eps, **kw)
        return HierPartition(part=part, tw=tw, pod_of=pod_of, lam=lam)

    # A. pods first, on Algorithm-1 targets aggregated per pod
    pod_topo = topo.pod_aggregate(pod_of)
    pod_tw = np.zeros(n_pods)
    np.add.at(pod_tw, pod_of, tw)
    vertex_pod = _dispatch(g, method, pod_tw, pod_topo.memories,
                           (n_pods,), seed, eps, **kw)

    # B. within each pod, on the leaf targets (rescaled to realized size)
    part = np.empty(g.n, dtype=np.int32)
    mems = topo.memories
    for p in range(n_pods):
        pus = np.flatnonzero(pod_of == p)
        mask = vertex_pod == p
        n_p = int(mask.sum())
        if n_p == 0:
            continue
        sub, ids = g.subgraph(mask)
        tw_p = tw[pus] * (n_p / max(tw[pus].sum(), 1e-12))
        if len(pus) == 1:
            part[ids] = pus[0]
            continue
        sub_part = _dispatch(sub, method, tw_p, mems[pus],
                             (len(pus),), seed + p + 1, eps, **kw)
        part[ids] = pus[sub_part]

    # C. pod-level sweep: co-optimize the pod assignment with the
    # realized partition (equal-spec blocks may trade pod slots)
    if refine:
        pairs, w = quotient_graph(g, part, topo.k)
        pod_of = refine_pod_assignment(pairs, w, pod_of,
                                       groups=_spec_groups(topo))
        # D. vertex-level FM against the weighted two-level objective
        part = refine_partition(g, part, tw, mems=mems, eps=eps,
                                pod_of=pod_of, lam=lam)
    return HierPartition(part=part, tw=tw, pod_of=pod_of, lam=lam)


METHODS = ("geoKM", "geoRef", "geoHier", "sfc", "rcb", "rib", "sfcRef",
           "greedyRef")


def evaluate(g: Graph, topo: Topology, methods=METHODS, seed: int = 0,
             pods=None, lam: float | None = None,
             verbose: bool = True) -> dict[str, dict]:
    """Run all methods; return {method: metrics+time} (Table IV analogue).

    With ``pods`` each method runs the pod-aware pipeline
    (:func:`partition_hier`) and the metrics include the intra/inter-pod
    split plus the weighted two-level objective."""
    out = {}
    tw = target_block_sizes(g.n, topo)
    for m in methods:
        t0 = time.perf_counter()
        if pods is None:
            part, _ = partition(g, topo, m, tw=tw, seed=seed)
            s = summarize(g, part, topo, tw)
        else:
            res = partition_hier(g, topo, m, pods=pods, tw=tw, seed=seed,
                                 lam=lam)
            part = res.part
            s = summarize_hier(g, part, topo, tw, res.pod_of, lam=res.lam)
        dt = time.perf_counter() - t0
        s["time_s"] = dt
        out[m] = s
        if verbose:
            line = (f"  {m:10s} cut={s['cut']:9.0f}"
                    f" maxCV={s['max_comm_volume']:6d}"
                    f" imb={s['imbalance']:.3f}"
                    f" memViol={s['mem_violations']}")
            if pods is not None:
                line += (f" interCV={s['comm_volume_inter']:6d}"
                         f" obj={s['two_level_objective']:9.0f}")
            print(line + f" t={dt:6.2f}s")
    return out
