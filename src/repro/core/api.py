"""Unified partitioning API — the black-box phase-2 interface of the paper.

``partition(graph, topology, method)`` runs the two-stage LDHT pipeline:
  stage 1: Algorithm 1 -> target block sizes tw (optimal for Eq. 2 + 3);
  stage 2: the chosen partitioner minimizes the cut (Eq. 1) under tw.

Methods (paper nomenclature):
  geoKM    — balanced k-means                      (Geographer)
  geoRef   — geoKM + multilevel pairwise-FM        (Geographer-R)
  geoHier  — hierarchical balanced k-means + refinement (Sec. V)
  sfc      — Morton space-filling curve            (zSFC analogue)
  rcb      — recursive coordinate bisection        (zRCB analogue)
  rib      — recursive inertial bisection          (zRIB analogue)
  sfcRef   — sfc + multilevel FM refinement        (ParMetisGeom-like:
             geometric initial partition + combinatorial refinement)
  greedyRef— BFS-greedy growing + multilevel FM    (ParMetisGraph-like:
             combinatorial initial partition + combinatorial refinement)
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np

from ..sparse.graph import Graph
from .balanced_kmeans import (partition_balanced_kmeans,
                              partition_hierarchical_kmeans)
from .block_sizes import target_block_sizes
from .metrics import summarize
from .multilevel import partition_multilevel_refine
from .rcb import partition_rcb
from .rib import partition_rib
from .sfc import partition_sfc
from .topology import Topology


def _greedy_growing(g: Graph, tw: np.ndarray, seed: int = 0) -> np.ndarray:
    """Combinatorial initial partition: multi-source BFS region growing with
    heterogeneous capacities (GGP — the classic Metis-style initializer)."""
    rng = np.random.default_rng(seed)
    k = len(tw)
    want = np.round(tw).astype(np.int64)
    want[np.argmax(want)] += g.n - want.sum()
    part = -np.ones(g.n, dtype=np.int32)
    # seeds: spread via random picks (BFS-farthest would be better; this is
    # the baseline tool, quality is allowed to be baseline-ish)
    seeds = rng.choice(g.n, size=k, replace=False)
    from collections import deque
    queues = [deque([int(s)]) for s in seeds]
    sizes = np.zeros(k, dtype=np.int64)
    for b, s in enumerate(seeds):
        if part[s] == -1:
            part[s] = b
            sizes[b] += 1
    active = True
    while active:
        active = False
        for b in np.argsort(sizes / np.maximum(want, 1)):
            if sizes[b] >= want[b] or not queues[b]:
                continue
            progressed = False
            while queues[b] and not progressed:
                v = queues[b].popleft()
                for u in g.indices[g.indptr[v]:g.indptr[v + 1]]:
                    if part[u] == -1 and sizes[b] < want[b]:
                        part[u] = b
                        sizes[b] += 1
                        queues[b].append(int(u))
                        progressed = True
                active = active or progressed
    # orphans (disconnected leftovers): assign to the most underloaded block
    for v in np.nonzero(part == -1)[0]:
        b = int(np.argmin(sizes / np.maximum(want, 1)))
        part[v] = b
        sizes[b] += 1
    return part


def partition(g: Graph, topo: Topology, method: str = "geoRef",
              tw: np.ndarray | None = None, seed: int = 0,
              eps: float = 0.03, **kw) -> tuple[np.ndarray, np.ndarray]:
    """Two-stage LDHT solve.  Returns (part, tw)."""
    if tw is None:
        tw = target_block_sizes(g.n, topo)
    mems = topo.memories
    if method == "geoKM":
        part = partition_balanced_kmeans(g, tw, seed=seed, **kw)
    elif method == "geoRef":
        part = partition_balanced_kmeans(g, tw, seed=seed, **kw)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps)
    elif method == "geoHier":
        part = partition_hierarchical_kmeans(g, tw, topo.fanouts, seed=seed,
                                             **kw)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps)
    elif method == "sfc":
        part = partition_sfc(g, tw, seed=seed)
    elif method == "rcb":
        part = partition_rcb(g, tw, seed=seed)
    elif method == "rib":
        part = partition_rib(g, tw, seed=seed)
    elif method == "sfcRef":
        part = partition_sfc(g, tw, seed=seed)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps)
    elif method == "greedyRef":
        part = _greedy_growing(g, tw, seed=seed)
        part = partition_multilevel_refine(g, part, tw, mems=mems, eps=eps)
    else:
        raise ValueError(f"unknown method {method!r}")
    return part.astype(np.int32), tw


METHODS = ("geoKM", "geoRef", "geoHier", "sfc", "rcb", "rib", "sfcRef",
           "greedyRef")


def evaluate(g: Graph, topo: Topology, methods=METHODS, seed: int = 0,
             verbose: bool = True) -> dict[str, dict]:
    """Run all methods; return {method: metrics+time} (Table IV analogue)."""
    out = {}
    tw = target_block_sizes(g.n, topo)
    for m in methods:
        t0 = time.perf_counter()
        part, _ = partition(g, topo, m, tw=tw, seed=seed)
        dt = time.perf_counter() - t0
        s = summarize(g, part, topo, tw)
        s["time_s"] = dt
        out[m] = s
        if verbose:
            print(f"  {m:10s} cut={s['cut']:9.0f} maxCV={s['max_comm_volume']:6d}"
                  f" imb={s['imbalance']:.3f} memViol={s['mem_violations']}"
                  f" t={dt:6.2f}s")
    return out
