"""Compute-system topology model for the LDHT problem.

The paper (Sec. II-B) represents the compute system as a tree T whose leaves
are the k processing units (PUs).  Each PU p_i carries two weights:

  * ``c_s(p_i)``    — normalized speed (operations / time unit)
  * ``m_cap(p_i)``  — memory capacity (same unit as vertex load)

Inner nodes accumulate the values of their children.  The hierarchical
balanced k-means (Sec. V) consumes the tree as a fan-out list
``k_1, ..., k_h`` with per-leaf specs.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class PU:
    """A processing unit (leaf of the topology tree)."""

    speed: float          # c_s(p_i) > 0
    memory: float         # m_cap(p_i) > 0
    name: str = ""

    def __post_init__(self):
        if self.speed <= 0:
            raise ValueError(f"PU speed must be positive, got {self.speed}")
        if self.memory <= 0:
            raise ValueError(f"PU memory must be positive, got {self.memory}")


@dataclasses.dataclass(frozen=True)
class Topology:
    """A (possibly hierarchical) compute topology.

    ``fanouts`` is the implicit-tree representation from Sec. V: a list
    ``[k_1, ..., k_h]`` with ``prod(fanouts) == len(pus)``.  A flat system is
    ``fanouts = [k]``.
    """

    pus: tuple[PU, ...]
    fanouts: tuple[int, ...] = ()

    def __post_init__(self):
        if not self.pus:
            raise ValueError("Topology needs at least one PU")
        fanouts = self.fanouts or (len(self.pus),)
        object.__setattr__(self, "fanouts", tuple(fanouts))
        if int(np.prod(self.fanouts)) != len(self.pus):
            raise ValueError(
                f"prod(fanouts)={np.prod(self.fanouts)} != k={len(self.pus)}")

    # -- aggregate quantities (Table I) ------------------------------------
    @property
    def k(self) -> int:
        return len(self.pus)

    @property
    def speeds(self) -> np.ndarray:
        return np.array([p.speed for p in self.pus], dtype=np.float64)

    @property
    def memories(self) -> np.ndarray:
        return np.array([p.memory for p in self.pus], dtype=np.float64)

    @property
    def total_speed(self) -> float:       # C_s
        return float(self.speeds.sum())

    @property
    def total_memory(self) -> float:      # M_cap
        return float(self.memories.sum())

    def feasible(self, n: float) -> bool:
        """A valid solution exists iff the load fits in total memory."""
        return n <= self.total_memory + 1e-12

    # -- implicit-tree structure (Sec. II-B / V) ---------------------------
    @property
    def depth(self) -> int:
        """h = len(fanouts): number of tree levels below the root.  A flat
        system is depth 1; the two-level pod machine of PRs 3-4 is the
        ``h == 2`` instance."""
        return len(self.fanouts)

    def ancestor_table(self, fanouts: Sequence[int] | None = None
                       ) -> np.ndarray:
        """Canonical (h-1, k) ancestor table of the implicit tree.

        Row ``t`` gives, per leaf, the id of its ancestor at tree depth
        ``t + 1`` (0 = the children of the root, coarsest): leaf ``i``
        written in ``fanouts`` mixed radix has ancestor
        ``i // prod(fanouts[t+1:])``.  For ``h == 2`` the single row is
        exactly :meth:`pod_assignment`'s contiguous pod grouping.  The
        table is the tree analogue of ``pod_of`` — the representation
        the tree metrics, the per-level KL sweep, and
        ``sparse.distributed.build_plan_tree`` all consume.
        """
        fanouts = tuple(fanouts) if fanouts is not None else self.fanouts
        return canonical_ancestors(fanouts)

    def level_of(self, i, j, fanouts: Sequence[int] | None = None):
        """Tree-distance level of PU pair (i, j): 0 = the pair shares its
        deepest internal node (fastest links), ``h - 1`` = only the root
        is shared (slowest links); -1 for ``i == j``.  Vectorized over
        array inputs.  This is the level whose ``LinkCosts`` entry a cut
        edge between blocks i and j pays."""
        fanouts = tuple(fanouts) if fanouts is not None else self.fanouts
        i = np.asarray(i, dtype=np.int64)
        j = np.asarray(j, dtype=np.int64)
        h = len(fanouts)
        shared = np.zeros(np.broadcast(i, j).shape, dtype=np.int64)
        size = int(np.prod(fanouts))
        for t in range(1, h):
            size //= fanouts[t - 1]            # subtree size at depth t
            shared += (i // size) == (j // size)
        level = h - 1 - shared
        level = np.where(i == j, -1, level)
        return level if level.ndim else int(level)

    def tree_aggregate(self, anc_row) -> "Topology":
        """Aggregate topology with one PU per group of ``anc_row`` — the
        per-level generalization of :meth:`pod_aggregate` (pass any row
        of the ancestor table to aggregate the corresponding tree level;
        the tree-aware Algorithm 1 water-fills these top-down)."""
        return self.pod_aggregate(anc_row)

    def pod_assignment(self, pods: int) -> np.ndarray:
        """(k,) pod id per PU: contiguous equal-size grouping of the PU
        list (``sparse.distributed.build_plan_hier``'s default).

        Algorithm-1 block sizes follow the PU order, and every paper
        topology lists the fast PUs first — so contiguous grouping puts
        the fast PUs (which own the largest blocks and therefore share
        the heaviest cut) inside one pod, where their exchange rides the
        fast intra-pod links.  When ``fanouts`` describes a two-level
        tree whose top fan-out equals ``pods`` (e.g. ``topo3``), the
        grouping coincides with the tree's node boundaries.
        """
        return contiguous_pods(self.k, pods)

    def pod_aggregate(self, pods) -> "Topology":
        """One-PU-per-pod aggregate topology (inner tree nodes, Sec. II-B).

        ``pods`` is a pod count (contiguous grouping via
        :meth:`pod_assignment`) or an explicit (k,) pod-of-PU array.
        Each aggregate PU carries the summed speed and memory of its
        members, so Algorithm 1 on the aggregate yields the per-pod
        block sizes of the two-level pipeline (``api.partition_hier``):
        the pod-level targets are exactly the per-pod sums of the leaf
        targets whenever no member is memory-saturated, and remain
        feasible (per-pod memory is the true per-pod capacity) when some
        are.
        """
        pod_of = normalize_pod_of(pods, self.k)
        n_pods = int(pod_of.max()) + 1
        speeds = np.zeros(n_pods)
        mems = np.zeros(n_pods)
        np.add.at(speeds, pod_of, self.speeds)
        np.add.at(mems, pod_of, self.memories)
        return Topology(tuple(PU(speeds[p], mems[p], f"pod{p}")
                              for p in range(n_pods)), (n_pods,))

    def link_costs(self, intra: float | None = None,
                   inter: float | None = None,
                   costs: Sequence[float] | None = None,
                   levels: int | None = None) -> "LinkCosts":
        """Per-cut-edge link-cost model for this topology's ``fanouts``
        tree: a cut edge between PUs i and j pays ``costs[level_of(i, j)]``
        — one unit for siblings, more per extra tree level the exchange
        must climb.  ``costs`` supplies the per-level vector directly
        (calibrate from measured round latencies); otherwise a geometric
        ladder ``intra * (inter/intra)**level`` over ``levels`` levels
        (default ``max(depth, 2)``) reproduces the two-level defaults
        (:data:`INTRA_LINK_COST` / :data:`INTER_LINK_COST`) at depth 2."""
        if costs is not None:
            return LinkCosts(costs=costs)
        intra = INTRA_LINK_COST if intra is None else intra
        inter = INTER_LINK_COST if inter is None else inter
        if levels is None:
            levels = max(self.depth, 2)
        if levels == 2:
            return LinkCosts(intra, inter)
        ratio = inter / intra
        return LinkCosts(costs=tuple(intra * ratio ** l
                                     for l in range(levels)))

    # -- constructors for the paper's simulated systems ---------------------
    @staticmethod
    def homogeneous(k: int, speed: float = 1.0, memory: float = 2.0,
                    fanouts: Sequence[int] | None = None) -> "Topology":
        return Topology(tuple(PU(speed, memory, f"pu{i}") for i in range(k)),
                        tuple(fanouts) if fanouts else (k,))

    @staticmethod
    def topo1(k: int, fast_fraction: float = 1 / 12,
              fast_speed: float = 2.0, fast_memory: float = 3.2) -> "Topology":
        """TOPO1 (Sec. VI-A): two sets, F (fast) and S (slow).

        Slow PUs always have speed 1 and memory 2 (Table III).  |F| = k/12 or
        k/6; the fast specs step through Table III rows.
        """
        n_fast = max(1, int(round(k * fast_fraction)))
        pus = [PU(fast_speed, fast_memory, f"fast{i}") for i in range(n_fast)]
        pus += [PU(1.0, 2.0, f"slow{i}") for i in range(k - n_fast)]
        return Topology(tuple(pus))

    @staticmethod
    def topo2(k: int, fast_fraction: float = 1 / 12,
              fast_speed: float = 2.0, fast_memory: float = 3.2) -> "Topology":
        """TOPO2 (Sec. VI-B): F + two slow groups S1, S2 with |S1| = |S2|.

        S2 PUs: speed 1, memory 2 (constant).  S1 PUs have memory 2 and speed
        chosen so that c_s(s1)/m_cap(s1) = (1/2) c_s(f)/m_cap(f)   (Eq. 5).
        """
        n_fast = max(1, int(round(k * fast_fraction)))
        n_slow = k - n_fast
        n_s1 = n_slow // 2
        n_s2 = n_slow - n_s1
        s1_speed = 0.5 * (fast_speed / fast_memory) * 2.0   # memory 2
        pus = [PU(fast_speed, fast_memory, f"fast{i}") for i in range(n_fast)]
        pus += [PU(s1_speed, 2.0, f"s1_{i}") for i in range(n_s1)]
        pus += [PU(1.0, 2.0, f"s2_{i}") for i in range(n_s2)]
        return Topology(tuple(pus))

    @staticmethod
    def topo3(nodes: int = 4, cores_per_node: int = 24, fast_nodes: int = 1,
              slow_speed: float = 0.5, slow_memory: float = 1.0) -> "Topology":
        """TOPO3 (Sec. VI-C): whole cluster nodes tuned down.

        ``fast_nodes`` nodes keep (1, 2); the rest get
        (slow_speed, slow_memory).  Hierarchical: fanouts = (nodes, cores).
        """
        pus = []
        for node in range(nodes):
            fast = node < fast_nodes
            for c in range(cores_per_node):
                pus.append(PU(1.0 if fast else slow_speed,
                              2.0 if fast else slow_memory,
                              f"n{node}c{c}"))
        return Topology(tuple(pus), fanouts=(nodes, cores_per_node))


# -- link-cost model over the topology tree ---------------------------------
#
# The tree runtime (sparse/distributed.py, comm="hier") pays one ppermute
# class per tree level at its own latency: level-0 rounds ride the fast
# innermost axes and overlap every slower exchange, while each outer level
# traverses progressively slower links (ICI < intra-node < DCN).  The
# per-cut-edge costs below are the relative round latencies that schedule
# implies — one unit for a sibling halo word, INTER_LINK_COST units per
# pod-crossing one (the ~4x DCN-vs-ICI gap the hier benchmark models);
# deeper trees default to the geometric ladder intra * (inter/intra)**lvl.
# The normalized vector is the per-level lambda of the tree objective
# (metrics.tree_objective) that the tree-aware refinement minimizes;
# override from measured round latencies when calibrating a real machine.

INTRA_LINK_COST = 1.0
INTER_LINK_COST = 4.0


@dataclasses.dataclass(frozen=True, init=False)
class LinkCosts:
    """Per-tree-level per-edge communication cost vector.

    ``costs[level]`` is the cost of one halo word between two PUs whose
    LCA sits ``level`` tree edges above them (``Topology.level_of``):
    ``costs[0]`` between siblings, ``costs[-1]`` across the root.  The
    two-positional-argument form ``LinkCosts(intra, inter)`` builds the
    ``h == 2`` instance of PR 4 (``intra``/``inter``/``lam`` keep their
    two-level meaning as views of the vector).
    """

    costs: tuple[float, ...]

    def __init__(self, intra: float | None = None,
                 inter: float | None = None, *,
                 costs: Sequence[float] | None = None):
        if costs is not None:
            if intra is not None or inter is not None:
                raise ValueError("pass either (intra, inter) or costs=, "
                                 "not both")
            costs = tuple(float(c) for c in costs)
        else:
            costs = (INTRA_LINK_COST if intra is None else float(intra),
                     INTER_LINK_COST if inter is None else float(inter))
        if not costs or any(c <= 0 for c in costs):
            raise ValueError("link costs must be positive")
        object.__setattr__(self, "costs", costs)

    @property
    def levels(self) -> int:
        return len(self.costs)

    @property
    def intra(self) -> float:
        """Innermost (sibling) per-edge cost — the cost unit."""
        return self.costs[0]

    @property
    def inter(self) -> float:
        """Outermost (root-crossing) per-edge cost."""
        return self.costs[-1]

    @property
    def lam(self) -> float:
        """lambda = inter/intra, the weight of the two-level objective."""
        return self.inter / self.intra

    @property
    def lams(self) -> tuple[float, ...]:
        """Per-level objective weights, normalized so ``lams[0] == 1``:
        the lambda vector of ``metrics.tree_objective``."""
        return tuple(c / self.costs[0] for c in self.costs)

    def matrix(self, pod_of: np.ndarray) -> np.ndarray:
        """(k, k) cost per block pair of the two-level instance: 0 on the
        diagonal, ``intra`` for same-pod pairs, ``inter`` for
        pod-crossing pairs."""
        pod_of = np.asarray(pod_of)
        same = pod_of[:, None] == pod_of[None, :]
        cost = np.where(same, self.intra, self.inter)
        np.fill_diagonal(cost, 0.0)
        return cost

    def tree_matrix(self, anc: np.ndarray) -> np.ndarray:
        """(k, k) cost per block pair under an (h-1, k) ancestor table:
        0 on the diagonal, ``costs[level]`` elsewhere, level = tree
        distance to the pair's LCA.  Needs ``levels >= h``."""
        lev = level_matrix(anc)
        if lev.max(initial=-1) >= self.levels:
            raise ValueError(f"ancestor table implies depth "
                             f"{lev.max() + 1} > {self.levels} cost levels")
        cost = np.asarray(self.costs)[np.maximum(lev, 0)]
        np.fill_diagonal(cost, 0.0)
        return cost


def normalize_pod_of(pods, k: int) -> np.ndarray:
    """``pods`` (pod count or explicit (k,) pod-of-block array) -> (k,)
    int64 pod ids.  The explicit path validates shape and equal pod sizes
    (the hier meshes are rectangular), mirroring
    ``sparse.distributed.build_plan_hier``."""
    if np.ndim(pods) == 0:
        return contiguous_pods(k, int(pods))
    pod_of = np.ascontiguousarray(pods, dtype=np.int64)
    if len(pod_of) != k:
        raise ValueError(f"pods array has {len(pod_of)} entries, "
                         f"expected k={k}")
    if pod_of.min() < 0:
        raise ValueError("pod ids must be >= 0")
    counts = np.bincount(pod_of, minlength=int(pod_of.max()) + 1)
    if not (counts == counts[0]).all():
        raise ValueError(f"pods must be equal-sized for a rectangular "
                         f"mesh; got sizes {counts.tolist()}")
    return pod_of


def contiguous_pods(k: int, pods: int) -> np.ndarray:
    """(k,) pod id per block: contiguous equal-size grouping — block b
    goes to pod ``b // (k // pods)``.  Requires ``pods | k`` (the
    two-level meshes are rectangular)."""
    if pods <= 0 or k % pods:
        raise ValueError(f"pods={pods} must divide k={k}")
    return np.arange(k, dtype=np.int64) // (k // pods)


def canonical_ancestors(fanouts: Sequence[int]) -> np.ndarray:
    """Canonical (h-1, k) ancestor table of the ``fanouts`` implicit tree:
    row ``t`` = ``leaf // prod(fanouts[t+1:])`` (contiguous nested
    grouping).  Row 0 of a two-level tree is :func:`contiguous_pods`."""
    fanouts = tuple(int(f) for f in fanouts)
    if not fanouts or any(f <= 0 for f in fanouts):
        raise ValueError(f"fanouts must be positive, got {fanouts}")
    k = int(np.prod(fanouts))
    leaves = np.arange(k, dtype=np.int64)
    rows = []
    size = k
    for t in range(len(fanouts) - 1):
        size //= fanouts[t]                    # subtree size at depth t+1
        rows.append(leaves // size)
    return (np.stack(rows) if rows
            else np.zeros((0, k), dtype=np.int64))


def level_matrix(anc: np.ndarray) -> np.ndarray:
    """(k, k) tree-distance level per block pair from an (h-1, k)
    ancestor table: 0 for pairs sharing every ancestor (siblings),
    ``h - 1`` for pairs sharing only the root; -1 on the diagonal."""
    anc = np.atleast_2d(np.asarray(anc, dtype=np.int64))
    h = anc.shape[0] + 1
    k = anc.shape[1]
    eq_all = np.ones((k, k), dtype=bool)
    shared = np.zeros((k, k), dtype=np.int64)
    for row in anc:
        eq_all &= row[:, None] == row[None, :]
        shared += eq_all
    lev = h - 1 - shared
    np.fill_diagonal(lev, -1)
    return lev


def normalize_tree_of(tree, k: int,
                      fanouts: Sequence[int] | None = None) -> np.ndarray:
    """Ancestor-table analogue of :func:`normalize_pod_of`: returns a
    validated (h-1, k) int64 table.

    Accepted forms: ``None`` (canonical contiguous table from
    ``fanouts``), a pod count or (k,) pod array (the two-level instance —
    one row), or a full (h-1, k) table.  Validation: every row groups the
    k blocks into equal-sized parts (the tree meshes are rectangular),
    rows are *nested* (each depth-(t+1) group lies inside one depth-t
    group), and — when ``fanouts`` is given — the group count of row t is
    ``prod(fanouts[:t+1])``.
    """
    if tree is None:
        if fanouts is None:
            raise ValueError("need fanouts when no ancestor table given")
        anc = canonical_ancestors(fanouts)
        if anc.shape[1] != k:
            raise ValueError(f"prod(fanouts)={anc.shape[1]} != k={k}")
        return anc
    arr = np.asarray(tree)
    if arr.ndim <= 1:                          # pods count or (k,) pod array
        anc = normalize_pod_of(tree, k)[None, :]
    else:
        anc = np.ascontiguousarray(arr, dtype=np.int64)
    if anc.shape[1] != k:
        raise ValueError(f"ancestor table has {anc.shape[1]} columns, "
                         f"expected k={k}")
    if fanouts is not None and anc.shape[0] != len(fanouts) - 1:
        raise ValueError(f"ancestor table has {anc.shape[0]} rows, "
                         f"fanouts {tuple(fanouts)} require "
                         f"{len(fanouts) - 1}")
    prev = np.zeros(k, dtype=np.int64)
    groups = 1
    for t, row in enumerate(anc):
        if row.min(initial=0) < 0:
            raise ValueError("ancestor ids must be >= 0")
        counts = np.bincount(row, minlength=int(row.max(initial=0)) + 1)
        if not (counts == counts[0]).all():
            raise ValueError(
                f"ancestor row {t} must group blocks equally for a "
                f"rectangular mesh; got sizes {counts.tolist()}")
        n_groups = len(counts)
        if fanouts is not None:
            groups *= int(fanouts[t])
            if n_groups != groups:
                raise ValueError(
                    f"ancestor row {t} has {n_groups} groups, "
                    f"fanouts {tuple(fanouts)} require {groups}")
        # nested: a depth-(t+1) group never straddles depth-t groups
        for gid in range(n_groups):
            if len(np.unique(prev[row == gid])) > 1:
                raise ValueError(
                    f"ancestor row {t} group {gid} straddles row "
                    f"{t - 1} groups — the table must be nested")
        prev = row
    return anc


def scale_to_load(topo: Topology, n: float,
                  headroom: float = 1.2) -> Topology:
    """Scale memory capacities so the total memory is ``headroom * n``.

    The paper's Table III specs are *relative* units.  With headroom 1.2 the
    implied tw(fast)/tw(slow) ratios of Table III's last column are
    reproduced exactly (9.4 for |F|=k/12, 11.5 for |F|=k/6 at fs=16).
    """
    u = headroom * n / topo.total_memory
    return Topology(tuple(PU(p.speed, p.memory * u, p.name)
                          for p in topo.pus), topo.fanouts)


# Table III of the paper: (speed, memory) of fast PUs per experiment step.
TABLE_III_FAST_SPECS: tuple[tuple[float, float], ...] = (
    (1.0, 2.0),     # exp 1 — homogeneous
    (2.0, 3.2),     # exp 2
    (4.0, 5.2),     # exp 3
    (8.0, 8.5),     # exp 4
    (16.0, 13.8),   # exp 5
)
