"""Compute-system topology model for the LDHT problem.

The paper (Sec. II-B) represents the compute system as a tree T whose leaves
are the k processing units (PUs).  Each PU p_i carries two weights:

  * ``c_s(p_i)``    — normalized speed (operations / time unit)
  * ``m_cap(p_i)``  — memory capacity (same unit as vertex load)

Inner nodes accumulate the values of their children.  The hierarchical
balanced k-means (Sec. V) consumes the tree as a fan-out list
``k_1, ..., k_h`` with per-leaf specs.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class PU:
    """A processing unit (leaf of the topology tree)."""

    speed: float          # c_s(p_i) > 0
    memory: float         # m_cap(p_i) > 0
    name: str = ""

    def __post_init__(self):
        if self.speed <= 0:
            raise ValueError(f"PU speed must be positive, got {self.speed}")
        if self.memory <= 0:
            raise ValueError(f"PU memory must be positive, got {self.memory}")


@dataclasses.dataclass(frozen=True)
class Topology:
    """A (possibly hierarchical) compute topology.

    ``fanouts`` is the implicit-tree representation from Sec. V: a list
    ``[k_1, ..., k_h]`` with ``prod(fanouts) == len(pus)``.  A flat system is
    ``fanouts = [k]``.
    """

    pus: tuple[PU, ...]
    fanouts: tuple[int, ...] = ()

    def __post_init__(self):
        if not self.pus:
            raise ValueError("Topology needs at least one PU")
        fanouts = self.fanouts or (len(self.pus),)
        object.__setattr__(self, "fanouts", tuple(fanouts))
        if int(np.prod(self.fanouts)) != len(self.pus):
            raise ValueError(
                f"prod(fanouts)={np.prod(self.fanouts)} != k={len(self.pus)}")

    # -- aggregate quantities (Table I) ------------------------------------
    @property
    def k(self) -> int:
        return len(self.pus)

    @property
    def speeds(self) -> np.ndarray:
        return np.array([p.speed for p in self.pus], dtype=np.float64)

    @property
    def memories(self) -> np.ndarray:
        return np.array([p.memory for p in self.pus], dtype=np.float64)

    @property
    def total_speed(self) -> float:       # C_s
        return float(self.speeds.sum())

    @property
    def total_memory(self) -> float:      # M_cap
        return float(self.memories.sum())

    def feasible(self, n: float) -> bool:
        """A valid solution exists iff the load fits in total memory."""
        return n <= self.total_memory + 1e-12

    def pod_assignment(self, pods: int) -> np.ndarray:
        """(k,) pod id per PU: contiguous equal-size grouping of the PU
        list (``sparse.distributed.build_plan_hier``'s default).

        Algorithm-1 block sizes follow the PU order, and every paper
        topology lists the fast PUs first — so contiguous grouping puts
        the fast PUs (which own the largest blocks and therefore share
        the heaviest cut) inside one pod, where their exchange rides the
        fast intra-pod links.  When ``fanouts`` describes a two-level
        tree whose top fan-out equals ``pods`` (e.g. ``topo3``), the
        grouping coincides with the tree's node boundaries.
        """
        return contiguous_pods(self.k, pods)

    def pod_aggregate(self, pods) -> "Topology":
        """One-PU-per-pod aggregate topology (inner tree nodes, Sec. II-B).

        ``pods`` is a pod count (contiguous grouping via
        :meth:`pod_assignment`) or an explicit (k,) pod-of-PU array.
        Each aggregate PU carries the summed speed and memory of its
        members, so Algorithm 1 on the aggregate yields the per-pod
        block sizes of the two-level pipeline (``api.partition_hier``):
        the pod-level targets are exactly the per-pod sums of the leaf
        targets whenever no member is memory-saturated, and remain
        feasible (per-pod memory is the true per-pod capacity) when some
        are.
        """
        pod_of = normalize_pod_of(pods, self.k)
        n_pods = int(pod_of.max()) + 1
        speeds = np.zeros(n_pods)
        mems = np.zeros(n_pods)
        np.add.at(speeds, pod_of, self.speeds)
        np.add.at(mems, pod_of, self.memories)
        return Topology(tuple(PU(speeds[p], mems[p], f"pod{p}")
                              for p in range(n_pods)), (n_pods,))

    def link_costs(self, intra: float | None = None,
                   inter: float | None = None) -> "LinkCosts":
        """Per-cut-edge link-cost model for this topology's two-level
        tree (``fanouts``): edges whose endpoints share a pod ride the
        fast intra-pod links, pod-crossing edges pay the slow top-level
        links.  Defaults come from the hier round latencies
        (:data:`INTRA_LINK_COST` / :data:`INTER_LINK_COST`)."""
        return LinkCosts(INTRA_LINK_COST if intra is None else intra,
                         INTER_LINK_COST if inter is None else inter)

    # -- constructors for the paper's simulated systems ---------------------
    @staticmethod
    def homogeneous(k: int, speed: float = 1.0, memory: float = 2.0,
                    fanouts: Sequence[int] | None = None) -> "Topology":
        return Topology(tuple(PU(speed, memory, f"pu{i}") for i in range(k)),
                        tuple(fanouts) if fanouts else (k,))

    @staticmethod
    def topo1(k: int, fast_fraction: float = 1 / 12,
              fast_speed: float = 2.0, fast_memory: float = 3.2) -> "Topology":
        """TOPO1 (Sec. VI-A): two sets, F (fast) and S (slow).

        Slow PUs always have speed 1 and memory 2 (Table III).  |F| = k/12 or
        k/6; the fast specs step through Table III rows.
        """
        n_fast = max(1, int(round(k * fast_fraction)))
        pus = [PU(fast_speed, fast_memory, f"fast{i}") for i in range(n_fast)]
        pus += [PU(1.0, 2.0, f"slow{i}") for i in range(k - n_fast)]
        return Topology(tuple(pus))

    @staticmethod
    def topo2(k: int, fast_fraction: float = 1 / 12,
              fast_speed: float = 2.0, fast_memory: float = 3.2) -> "Topology":
        """TOPO2 (Sec. VI-B): F + two slow groups S1, S2 with |S1| = |S2|.

        S2 PUs: speed 1, memory 2 (constant).  S1 PUs have memory 2 and speed
        chosen so that c_s(s1)/m_cap(s1) = (1/2) c_s(f)/m_cap(f)   (Eq. 5).
        """
        n_fast = max(1, int(round(k * fast_fraction)))
        n_slow = k - n_fast
        n_s1 = n_slow // 2
        n_s2 = n_slow - n_s1
        s1_speed = 0.5 * (fast_speed / fast_memory) * 2.0   # memory 2
        pus = [PU(fast_speed, fast_memory, f"fast{i}") for i in range(n_fast)]
        pus += [PU(s1_speed, 2.0, f"s1_{i}") for i in range(n_s1)]
        pus += [PU(1.0, 2.0, f"s2_{i}") for i in range(n_s2)]
        return Topology(tuple(pus))

    @staticmethod
    def topo3(nodes: int = 4, cores_per_node: int = 24, fast_nodes: int = 1,
              slow_speed: float = 0.5, slow_memory: float = 1.0) -> "Topology":
        """TOPO3 (Sec. VI-C): whole cluster nodes tuned down.

        ``fast_nodes`` nodes keep (1, 2); the rest get
        (slow_speed, slow_memory).  Hierarchical: fanouts = (nodes, cores).
        """
        pus = []
        for node in range(nodes):
            fast = node < fast_nodes
            for c in range(cores_per_node):
                pus.append(PU(1.0 if fast else slow_speed,
                              2.0 if fast else slow_memory,
                              f"n{node}c{c}"))
        return Topology(tuple(pus), fanouts=(nodes, cores_per_node))


# -- link-cost model over the two-level topology tree -----------------------
#
# The hier runtime (sparse/distributed.py, comm="hier") pays its two
# ppermute classes at different latencies: intra-pod rounds ride the fast
# per-pod axes and overlap the inter-pod exchange, while every inter-pod
# round traverses the slow combined-axes links.  The per-cut-edge costs
# below are the relative round latencies that schedule implies — one unit
# for an intra-pod halo word, INTER_LINK_COST units for an inter-pod one
# (the ~4x DCN-vs-ICI gap the hier benchmark models).  Their ratio is the
# lambda of the weighted two-level objective (metrics.two_level_objective)
# that the pod-aware refinement minimizes; override from measured round
# latencies when calibrating a real machine.

INTRA_LINK_COST = 1.0
INTER_LINK_COST = 4.0


@dataclasses.dataclass(frozen=True)
class LinkCosts:
    """Intra-pod vs inter-pod per-edge communication cost."""

    intra: float = INTRA_LINK_COST
    inter: float = INTER_LINK_COST

    def __post_init__(self):
        if self.intra <= 0 or self.inter <= 0:
            raise ValueError("link costs must be positive")

    @property
    def lam(self) -> float:
        """lambda = inter/intra, the weight of the two-level objective."""
        return self.inter / self.intra

    def matrix(self, pod_of: np.ndarray) -> np.ndarray:
        """(k, k) cost per block pair: 0 on the diagonal, ``intra`` for
        same-pod pairs, ``inter`` for pod-crossing pairs."""
        pod_of = np.asarray(pod_of)
        same = pod_of[:, None] == pod_of[None, :]
        cost = np.where(same, self.intra, self.inter)
        np.fill_diagonal(cost, 0.0)
        return cost


def normalize_pod_of(pods, k: int) -> np.ndarray:
    """``pods`` (pod count or explicit (k,) pod-of-block array) -> (k,)
    int64 pod ids.  The explicit path validates shape and equal pod sizes
    (the hier meshes are rectangular), mirroring
    ``sparse.distributed.build_plan_hier``."""
    if np.ndim(pods) == 0:
        return contiguous_pods(k, int(pods))
    pod_of = np.ascontiguousarray(pods, dtype=np.int64)
    if len(pod_of) != k:
        raise ValueError(f"pods array has {len(pod_of)} entries, "
                         f"expected k={k}")
    if pod_of.min() < 0:
        raise ValueError("pod ids must be >= 0")
    counts = np.bincount(pod_of, minlength=int(pod_of.max()) + 1)
    if not (counts == counts[0]).all():
        raise ValueError(f"pods must be equal-sized for a rectangular "
                         f"mesh; got sizes {counts.tolist()}")
    return pod_of


def contiguous_pods(k: int, pods: int) -> np.ndarray:
    """(k,) pod id per block: contiguous equal-size grouping — block b
    goes to pod ``b // (k // pods)``.  Requires ``pods | k`` (the
    two-level meshes are rectangular)."""
    if pods <= 0 or k % pods:
        raise ValueError(f"pods={pods} must divide k={k}")
    return np.arange(k, dtype=np.int64) // (k // pods)


def scale_to_load(topo: Topology, n: float,
                  headroom: float = 1.2) -> Topology:
    """Scale memory capacities so the total memory is ``headroom * n``.

    The paper's Table III specs are *relative* units.  With headroom 1.2 the
    implied tw(fast)/tw(slow) ratios of Table III's last column are
    reproduced exactly (9.4 for |F|=k/12, 11.5 for |F|=k/6 at fs=16).
    """
    u = headroom * n / topo.total_memory
    return Topology(tuple(PU(p.speed, p.memory * u, p.name)
                          for p in topo.pus), topo.fanouts)


# Table III of the paper: (speed, memory) of fast PUs per experiment step.
TABLE_III_FAST_SPECS: tuple[tuple[float, float], ...] = (
    (1.0, 2.0),     # exp 1 — homogeneous
    (2.0, 3.2),     # exp 2
    (4.0, 5.2),     # exp 3
    (8.0, 8.5),     # exp 4
    (16.0, 13.8),   # exp 5
)
