"""LDHT core: the paper's contribution as a composable library."""
from .api import METHODS, evaluate, partition
from .block_sizes import (hetero_batch_split, max_load_ratio,
                          target_block_sizes, target_block_sizes_jax)
from .topology import (PU, TABLE_III_FAST_SPECS, Topology,
                       contiguous_pods, scale_to_load)

__all__ = [
    "METHODS", "evaluate", "partition", "target_block_sizes",
    "target_block_sizes_jax", "hetero_batch_split", "max_load_ratio",
    "PU", "Topology", "scale_to_load", "contiguous_pods",
    "TABLE_III_FAST_SPECS",
]
