"""LDHT core: the paper's contribution as a composable library."""
from .api import (HierPartition, METHODS, evaluate, partition,
                  partition_hier, pod_assignment_for)
from .block_sizes import (hetero_batch_split, max_load_ratio,
                          target_block_sizes, target_block_sizes_jax)
from .topology import (INTER_LINK_COST, INTRA_LINK_COST, LinkCosts, PU,
                       TABLE_III_FAST_SPECS, Topology, contiguous_pods,
                       normalize_pod_of, scale_to_load)

__all__ = [
    "METHODS", "evaluate", "partition", "partition_hier", "HierPartition",
    "pod_assignment_for", "target_block_sizes", "target_block_sizes_jax",
    "hetero_batch_split", "max_load_ratio", "PU", "Topology",
    "scale_to_load", "contiguous_pods", "normalize_pod_of", "LinkCosts",
    "INTRA_LINK_COST", "INTER_LINK_COST", "TABLE_III_FAST_SPECS",
]
