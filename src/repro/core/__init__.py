"""LDHT core: the paper's contribution as a composable library."""
from .api import (HierPartition, METHODS, evaluate, partition,
                  partition_hier, partition_tree, pod_assignment_for,
                  tree_assignment_for)
from .block_sizes import (hetero_batch_split, max_load_ratio,
                          target_block_sizes, target_block_sizes_jax,
                          tree_target_block_sizes, waterfill)
from .costmodel import (BottleneckCost, COST_MODELS, CostModel, CutCost,
                        cost_model_for)
from .topology import (INTER_LINK_COST, INTRA_LINK_COST, LinkCosts, PU,
                       TABLE_III_FAST_SPECS, Topology, canonical_ancestors,
                       contiguous_pods, level_matrix, normalize_pod_of,
                       normalize_tree_of, scale_to_load)

__all__ = [
    "METHODS", "evaluate", "partition", "partition_hier", "partition_tree",
    "HierPartition", "pod_assignment_for", "tree_assignment_for",
    "target_block_sizes", "target_block_sizes_jax",
    "tree_target_block_sizes", "waterfill",
    "hetero_batch_split", "max_load_ratio", "PU", "Topology",
    "scale_to_load", "canonical_ancestors", "contiguous_pods",
    "level_matrix", "normalize_pod_of", "normalize_tree_of", "LinkCosts",
    "INTRA_LINK_COST", "INTER_LINK_COST", "TABLE_III_FAST_SPECS",
    "CostModel", "CutCost", "BottleneckCost", "COST_MODELS",
    "cost_model_for",
]
