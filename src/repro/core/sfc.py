"""Space-filling-curve partitioner (zSFC analogue, Sec. III-a).

Sort vertices by Morton code, then slice the order at the cumulative target
weights from Algorithm 1.  O(n log n), embarrassingly parallel, lowest
quality of the geometric family — exactly the paper's baseline role.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from ..sparse.graph import Graph
from .geometry import morton_codes, weighted_split_assignment


def partition_sfc(g: Graph, tw: np.ndarray, seed: int = 0) -> np.ndarray:
    assert g.coords is not None, "SFC needs coordinates"
    codes = np.asarray(morton_codes(jnp.asarray(g.coords)))
    order = np.argsort(codes, kind="stable")
    return weighted_split_assignment(order, np.asarray(tw))
