"""Multilevel Geographer-R (Sec. V): partition-first multilevel refinement.

Contrary to the classic multilevel approach, the partition is obtained
*before* coarsening (via balanced k-means).  Each block then coarsens its
local subgraph with heavy-edge matching — matching never crosses block
boundaries, so the partition projects exactly onto every level.  During
uncoarsening, the scheduled pairwise-FM refinement of ``refinement.py`` runs
at each level (cheap at coarse levels, touching only boundaries at fine
ones).
"""
from __future__ import annotations

import numpy as np

from ..sparse.graph import Graph, from_edges
from .refinement import refine_partition


def heavy_edge_matching(g: Graph, part: np.ndarray,
                        seed: int = 0) -> np.ndarray:
    """Greedy heavy-edge matching restricted to intra-block edges.

    Returns match (n,) — match[v] = u if {u, v} matched, else v.
    Visits vertices in random order; each picks its heaviest unmatched
    same-block neighbor (Metis-style HEM).
    """
    rng = np.random.default_rng(seed)
    match = np.arange(g.n)
    matched = np.zeros(g.n, dtype=bool)
    for v in rng.permutation(g.n):
        if matched[v]:
            continue
        row = slice(g.indptr[v], g.indptr[v + 1])
        nb, wv = g.indices[row], g.weights[row]
        ok = (~matched[nb]) & (part[nb] == part[v]) & (nb != v)
        if not ok.any():
            continue
        u = nb[ok][np.argmax(wv[ok])]
        match[v], match[u] = u, v
        matched[v] = matched[u] = True
    return match


def contract(g: Graph, part: np.ndarray, match: np.ndarray):
    """Contract matched pairs.  Returns (coarse_graph, coarse_part, fine2coarse).

    Vertex weights are carried in ``coarse_vw`` so balance stays exact.
    """
    rep = np.minimum(np.arange(g.n), match)       # canonical endpoint
    uniq, fine2coarse = np.unique(rep, return_inverse=True)
    nc = len(uniq)
    src, dst, w = g.edge_list()
    cs, cd = fine2coarse[src], fine2coarse[dst]
    keep = cs != cd
    coords = None
    if g.coords is not None:
        coords = np.zeros((nc, g.coords.shape[1]), dtype=np.float64)
        np.add.at(coords, fine2coarse, g.coords.astype(np.float64))
        cnt = np.bincount(fine2coarse, minlength=nc)
        coords = (coords / cnt[:, None]).astype(np.float32)
    cg = from_edges(nc, cs[keep], cd[keep], w[keep], coords=coords)
    cvw = np.bincount(fine2coarse, minlength=nc)  # vertices per supernode
    return cg, part[uniq].copy(), fine2coarse, cvw


def partition_multilevel_refine(g: Graph, part0: np.ndarray, tw: np.ndarray,
                                mems: np.ndarray | None = None,
                                eps: float = 0.03, max_levels: int = 4,
                                coarsest: int = 4096, passes: int = 2,
                                seed: int = 0, verbose: bool = False
                                ) -> np.ndarray:
    """Geographer-R refinement given an initial partition (e.g. geoKM).

    On coarse levels supernodes have weight > 1; the per-level supernode
    weights (``contract``'s ``cvw``) are threaded into the pairwise FM's
    size/cap accounting, so the heterogeneous caps (Eq. 3) hold in true
    vertex units at every level — a heavy supernode cannot slip into a
    block whose *mean*-scaled cap would have admitted it.  Boundary-exact
    refinement happens at the finest level (unit weights there).
    """
    graphs = [g]
    parts = [np.asarray(part0, dtype=np.int32).copy()]
    maps: list[np.ndarray] = []
    vws = [np.ones(g.n, dtype=np.int64)]
    for lvl in range(max_levels):
        cur, cpart = graphs[-1], parts[-1]
        if cur.n <= coarsest:
            break
        match = heavy_edge_matching(cur, cpart, seed=seed + lvl)
        cg, cp, f2c, _cvw = contract(cur, cpart, match)
        if cg.n >= cur.n * 0.95:      # matching stalled
            break
        graphs.append(cg)
        parts.append(cp)
        maps.append(f2c)
        # cumulative weight in *finest*-vertex units (not the previous
        # level's supernode count): caps stay comparable across levels
        vws.append(np.bincount(f2c, weights=vws[-1],
                               minlength=cg.n).astype(np.int64))
        if verbose:
            print(f"  level {lvl + 1}: {cg.n} vertices")

    # refine coarsest -> finest: targets/caps stay in true vertex units,
    # the per-level supernode weights carry the size accounting
    for lvl in range(len(graphs) - 1, -1, -1):
        parts[lvl] = refine_partition(graphs[lvl], parts[lvl], tw,
                                      mems=mems, eps=eps, passes=passes,
                                      vw=None if lvl == 0 else vws[lvl],
                                      verbose=verbose)
        if lvl > 0:
            parts[lvl - 1] = parts[lvl][maps[lvl - 1]]
    return parts[0]
