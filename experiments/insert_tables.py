"""Regenerate the §Roofline tables inside EXPERIMENTS.md from the dry-run
JSON artifacts.  Idempotent: replaces everything between the
ROOFLINE-TABLES marker and the next '---' rule.

  PYTHONPATH=src python experiments/insert_tables.py
"""
import io
import re
import sys
from contextlib import redirect_stdout
from pathlib import Path

sys.path.insert(0, "src")

from repro.launch.report import HEADER, fmt_row, load  # noqa: E402

ROOT = Path(__file__).resolve().parent.parent
MD = ROOT / "EXPERIMENTS.md"
MARK = "<!-- ROOFLINE-TABLES -->"


def table(dirname: str, mesh: str) -> str:
    recs = load(ROOT / "experiments" / dirname, mesh)
    rows = "\n".join(fmt_row(r) for r in recs)
    return f"{HEADER}\n{rows}"


def main():
    parts = [MARK, ""]
    parts.append("### Optimized defaults — single pod 16×16 "
                 "(experiments/dryrun_opt)\n")
    parts.append(table("dryrun_opt", "16x16"))
    parts.append("\n### Optimized defaults — multi-pod 2×16×16 "
                 "(proves the `pod` axis shards)\n")
    parts.append(table("dryrun_opt", "2x16x16"))
    parts.append("\n### Paper-faithful baseline — single pod 16×16 "
                 "(experiments/dryrun, pre-correction collective parser)\n")
    parts.append(table("dryrun", "16x16"))
    block = "\n".join(parts) + "\n"

    text = MD.read_text()
    pat = re.compile(re.escape(MARK) + r".*?(?=\n---)", re.S)
    assert pat.search(text), "marker not found"
    MD.write_text(pat.sub(lambda _: block, text))
    print("tables inserted")


if __name__ == "__main__":
    main()
